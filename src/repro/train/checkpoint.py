"""Fault-tolerant checkpointing: atomic, keep-N, verified resume-latest.

Layout:  <dir>/step_<N>/manifest.json + leaf_<i>.npy (one per pytree leaf).
Writes go to a temp directory then os.rename (atomic on POSIX) — a crash
mid-save never corrupts the latest checkpoint, and `_gc` sweeps any
`.tmp_save_*` litter such a crash leaves behind. Restore optionally
re-shards onto a (possibly different-sized) mesh — the elastic-restart
path.

Integrity: every leaf is checksummed (CRC32 of the raw array bytes) into
the manifest at save time. `restore` verifies manifest parse, leaf
presence, shape/dtype, and checksum, raising `CheckpointCorrupt` on any
mismatch; `restore_latest` walks checkpoints newest-to-oldest and falls
back past corrupt/partial ones to the newest VALID step instead of
crashing — torn writes, bit rot, and half-deleted directories cost at
most `keep - 1` steps of progress, never the run. Checkpoints written
before checksums existed restore fine (verification of a missing `crc32`
field is skipped).

Fault injection: `repro.resilience` arms the `ckpt_truncate` site here —
`save` deterministically corrupts the checkpoint it just wrote, which is
exactly the failure `restore_latest`'s fallback must absorb.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import warnings
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.obs import trace as obs_trace
from repro.resilience import faults


class CheckpointCorrupt(RuntimeError):
    """A checkpoint directory failed verification (missing/truncated
    files, checksum or shape mismatch, unparseable manifest)."""


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(kp) for kp, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _step_dirs(ckpt_dir: str) -> List[Tuple[int, str]]:
    """(step, dirname) for every well-formed step_* entry, ascending.
    Malformed names (step_garbage) and `.tmp_save_*` litter are skipped
    rather than crashing `int(...)`."""
    out = []
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_"):
            continue
        try:
            out.append((int(d.split("_", 1)[1]), d))
        except ValueError:
            continue
    return sorted(out)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomically write `tree` (+ JSON-able `extra`) as step `step`."""
    # cat="sync": np.asarray below drains every device leaf to host —
    # this is one of the trainer's sanctioned boundary syncs
    with obs_trace.span("ckpt_save", cat="sync", step=step):
        return _save(ckpt_dir, step, tree, extra, keep)


def _save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict],
          keep: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, paths, _ = _flatten_with_paths(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_save_")
    try:
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (leaf, path) in enumerate(zip(leaves, paths)):
            arr = np.asarray(leaf)
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            manifest["leaves"].append(
                {"i": i, "path": path, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "crc32": _crc(arr)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:09d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    spec = faults.fire("ckpt_truncate", step=step)
    if spec is not None:
        # chaos site: damage the checkpoint we just wrote (torn write /
        # bit rot) — restore_latest must fall back past it
        faults.corrupt_checkpoint(final, faults.active().payload_rng(spec))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = _step_dirs(ckpt_dir)
    for _, d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    for d in os.listdir(ckpt_dir):
        # a crash between mkdtemp and rename leaves .tmp_save_* litter;
        # our own tmp dir is already renamed away by the time _gc runs
        if d.startswith(".tmp_save_"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = _step_dirs(ckpt_dir)
    return steps[-1][0] if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> tuple:
    """Restore into the structure of `like`, verifying the manifest and
    every leaf (presence, shape/dtype, CRC32) — raises
    `CheckpointCorrupt` instead of returning silently wrong state. If
    `shardings` is given each leaf is device_put with its sharding (the
    elastic reshard happens here)."""
    with obs_trace.span("ckpt_restore", cat="ckpt", step=step):
        return _restore(ckpt_dir, step, like, shardings)


def _restore(ckpt_dir: str, step: int, like: Any, shardings: Any) -> tuple:
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        refs = manifest["leaves"]
    except (OSError, ValueError, KeyError, TypeError) as e:
        raise CheckpointCorrupt(f"{path}: unreadable manifest: {e}") from e
    leaves, _, treedef = _flatten_with_paths(like)
    if len(leaves) != len(refs):
        raise CheckpointCorrupt(
            f"{path}: leaf count mismatch: restore target has "
            f"{len(leaves)}, manifest has {len(refs)}")
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for i, ref in enumerate(leaves):
        try:
            arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        except (OSError, ValueError, EOFError) as e:
            raise CheckpointCorrupt(
                f"{path}: leaf_{i}.npy unreadable: {e}") from e
        meta = refs[i]
        if tuple(arr.shape) != tuple(meta.get("shape", arr.shape)) or \
                str(arr.dtype) != meta.get("dtype", str(arr.dtype)):
            raise CheckpointCorrupt(
                f"{path}: leaf {i} shape/dtype {arr.shape}/{arr.dtype} "
                f"!= manifest {meta.get('shape')}/{meta.get('dtype')}")
        if "crc32" in meta and _crc(arr) != meta["crc32"]:
            raise CheckpointCorrupt(f"{path}: leaf {i} checksum mismatch")
        if tuple(arr.shape) != tuple(ref.shape):
            raise CheckpointCorrupt(
                f"{path}: shape mismatch at leaf {i}: {arr.shape} vs "
                f"{ref.shape}")
        if shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def restore_latest(ckpt_dir: str, like: Any, shardings: Any = None,
                   on_corrupt: Optional[Callable[[int, Exception],
                                                 None]] = None):
    """Restore the newest VALID checkpoint, falling back past corrupt or
    partial ones (each skip warns and invokes `on_corrupt(step, err)` for
    metering). Returns (None, None, None) when no valid checkpoint
    exists — same as an empty directory."""
    if not os.path.isdir(ckpt_dir):
        return None, None, None
    for step, _ in reversed(_step_dirs(ckpt_dir)):
        try:
            tree, extra = restore(ckpt_dir, step, like, shardings)
        except CheckpointCorrupt as e:
            warnings.warn(f"skipping corrupt checkpoint step {step}: {e}",
                          RuntimeWarning, stacklevel=2)
            if on_corrupt is not None:
                on_corrupt(step, e)
            continue
        return step, tree, extra
    return None, None, None
