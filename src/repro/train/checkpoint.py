"""Fault-tolerant checkpointing: atomic, keep-N, resume-latest.

Layout:  <dir>/step_<N>/manifest.json + leaf_<i>.npy (one per pytree leaf).
Writes go to a temp directory then os.rename (atomic on POSIX) — a crash
mid-save never corrupts the latest checkpoint. Restore optionally re-shards
onto a (possibly different-sized) mesh — the elastic-restart path.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(kp) for kp, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomically write `tree` (+ JSON-able `extra`) as step `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, paths, _ = _flatten_with_paths(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_save_")
    try:
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (leaf, path) in enumerate(zip(leaves, paths)):
            arr = np.asarray(leaf)
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            manifest["leaves"].append(
                {"i": i, "path": path, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:09d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> tuple:
    """Restore into the structure of `like`. If `shardings` is given each
    leaf is device_put with its sharding (elastic reshard happens here)."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, _, treedef = _flatten_with_paths(like)
    assert len(leaves) == len(manifest["leaves"]), \
        f"leaf count mismatch: {len(leaves)} vs {len(manifest['leaves'])}"
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        assert tuple(arr.shape) == tuple(ref.shape), \
            f"shape mismatch at leaf {i}: {arr.shape} vs {ref.shape}"
        if shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def restore_latest(ckpt_dir: str, like: Any, shardings: Any = None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None, None
    tree, extra = restore(ckpt_dir, step, like, shardings)
    return step, tree, extra
