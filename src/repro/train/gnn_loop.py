"""GNN mini-batch training loop — mirrors the paper's methodology (§5):
AdamW(lr=1e-3, wd=5e-4), batch 1024, fanout 10 per hop, up to 100 epochs,
early stopping on val loss (patience 6), ReduceLROnPlateau (patience 3),
metrics: final val acc, per-epoch time, epochs-to-converge, total time, and
the Fig-6 working-set metric (mean unique input nodes / feature bytes).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CommRandPolicy, GNNConfig, TrainConfig
from repro.core import minibatch as mb
from repro.core import partition
from repro.graphs.csr import DeviceGraph, Graph
from repro.models.gnn.models import apply_gnn, init_gnn
from repro.optim import adamw
from repro.optim.schedule import EarlyStopping, ReduceLROnPlateau
from repro.train.losses import accuracy, gnn_softmax_ce


@dataclass
class EpochMetrics:
    epoch: int
    train_loss: float
    val_loss: float
    val_acc: float
    epoch_time_s: float
    mean_unique_nodes: float


@dataclass
class TrainResult:
    policy: str
    val_acc: float                  # at best epoch
    test_acc: float
    epochs_to_converge: int
    per_epoch_time_s: float
    total_time_s: float
    mean_unique_nodes: float
    feature_bytes_per_batch: float
    caps: tuple
    history: List[EpochMetrics] = field(default_factory=list)


def _make_steps(cfg: GNNConfig, tcfg: TrainConfig, caps, fanouts):
    @functools.partial(jax.jit, static_argnames=())
    def train_step(params, opt_state, batch: mb.MiniBatch, feats, degrees,
                   lr, key):
        def loss_fn(p):
            x = feats[jnp.minimum(batch.node_ids, feats.shape[0] - 1)]
            logits = apply_gnn(cfg, p, batch, x, degrees, train=True,
                               dropout_key=key)
            return gnn_softmax_ce(logits, batch.labels,
                                  batch.label_mask.astype(jnp.float32))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt = adamw.update(
            grads, opt_state, params, lr=lr,
            weight_decay=tcfg.weight_decay)
        return new_params, new_opt, loss

    @jax.jit
    def eval_step(params, batch: mb.MiniBatch, feats, degrees):
        x = feats[jnp.minimum(batch.node_ids, feats.shape[0] - 1)]
        logits = apply_gnn(cfg, params, batch, x, degrees, train=False)
        m = batch.label_mask.astype(jnp.float32)
        return (gnn_softmax_ce(logits, batch.labels, m),
                accuracy(logits, batch.labels, m), m.sum())

    return train_step, eval_step


class GNNTrainer:
    """One (graph, model, policy) training run."""

    def __init__(self, graph: Graph, cfg: GNNConfig, tcfg: TrainConfig,
                 policy: CommRandPolicy, caps=None, eval_caps=None,
                 seed: int = 0):
        self.graph = graph
        self.cfg = cfg
        self.tcfg = tcfg
        self.policy = policy
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.key(seed)
        self.g = DeviceGraph.from_graph(graph)
        self.feats = jnp.asarray(graph.features)
        self.labels = jnp.asarray(graph.labels)
        self.degrees = self.g.degrees
        self.fanouts = tuple(cfg.fanout[:cfg.num_layers])
        self.caps = caps or mb.calibrate_caps(
            graph, policy, tcfg.batch_size, self.fanouts, seed=seed)
        # eval always uses the uniform policy (identical across compared
        # policies) — calibrate once with p=0.5
        self.eval_policy = CommRandPolicy("rand", 0.0, 0.5)
        self.eval_caps = eval_caps or mb.calibrate_caps(
            graph, self.eval_policy, tcfg.batch_size, self.fanouts,
            seed=seed + 1)
        self.train_step, self.eval_step = _make_steps(
            cfg, tcfg, self.caps, self.fanouts)
        self.params = init_gnn(cfg, jax.random.key(seed))
        self.opt_state = adamw.init(self.params)

    def _build(self, roots_np, caps, p):
        self.key, k = jax.random.split(self.key)
        roots = jnp.asarray(roots_np, jnp.int32)
        return mb.build_batch(k, self.g, roots, self.labels, self.fanouts,
                              caps, p)

    def warmup(self):
        """Trigger all jit compilations without disturbing training state
        (so per-epoch timings measure steady-state throughput)."""
        saved = (jax.tree.map(lambda x: x, self.params),
                 jax.tree.map(lambda x: x, self.opt_state))
        roots = np.full(self.tcfg.batch_size, -1, np.int64)
        roots[:min(len(self.graph.train_ids), 8)] = \
            self.graph.train_ids[:8]
        b = self._build(roots, self.caps, self.policy.p)
        self.params, self.opt_state, _ = self.train_step(
            self.params, self.opt_state, b, self.feats, self.degrees,
            0.0, jax.random.key(0))
        be = self._build(roots, self.eval_caps, self.eval_policy.p)
        self.eval_step(self.params, be, self.feats, self.degrees)
        self.params, self.opt_state = saved
        return self

    def run_epoch(self, lr: float) -> Dict:
        t0 = time.perf_counter()
        batches = partition.batches_for_epoch(
            self.graph.train_ids, self.graph.communities, self.policy,
            self.tcfg.batch_size, self.rng)
        losses, uniq = [], []
        for b in batches:
            batch = self._build(b, self.caps, self.policy.p)
            self.key, k = jax.random.split(self.key)
            self.params, self.opt_state, loss = self.train_step(
                self.params, self.opt_state, batch, self.feats,
                self.degrees, lr, k)
            losses.append(loss)
            uniq.append(batch.num_unique)
        jax.block_until_ready(losses[-1])
        dt = time.perf_counter() - t0
        return {"loss": float(np.mean([float(l) for l in losses])),
                "time": dt,
                "uniq": float(np.mean([float(u) for u in uniq]))}

    def evaluate(self, ids: np.ndarray) -> Dict:
        tot_l, tot_a, tot_n = 0.0, 0.0, 0.0
        for i in range(0, len(ids), self.tcfg.batch_size):
            chunk = ids[i:i + self.tcfg.batch_size]
            pad = np.full(self.tcfg.batch_size, -1, np.int64)
            pad[:len(chunk)] = chunk
            batch = self._build(pad, self.eval_caps, self.eval_policy.p)
            l, a, n = self.eval_step(self.params, batch, self.feats,
                                     self.degrees)
            n = float(n)
            tot_l += float(l) * n
            tot_a += float(a) * n
            tot_n += n
        return {"loss": tot_l / max(tot_n, 1), "acc": tot_a / max(tot_n, 1)}

    def fit(self, verbose: bool = False) -> TrainResult:
        stopper = EarlyStopping(self.tcfg.early_stop_patience)
        plateau = ReduceLROnPlateau(self.tcfg.learning_rate,
                                    self.tcfg.plateau_factor,
                                    self.tcfg.plateau_patience)
        history: List[EpochMetrics] = []
        best_val_acc, best_params = 0.0, self.params
        lr = self.tcfg.learning_rate
        t_start = time.perf_counter()
        for epoch in range(self.tcfg.max_epochs):
            em = self.run_epoch(lr)
            ev = self.evaluate(self.graph.val_ids)
            history.append(EpochMetrics(epoch, em["loss"], ev["loss"],
                                        ev["acc"], em["time"], em["uniq"]))
            if verbose:
                print(f"  epoch {epoch:3d} loss={em['loss']:.4f} "
                      f"val={ev['acc']:.4f} t={em['time']:.2f}s "
                      f"uniq={em['uniq']:.0f}")
            if ev["acc"] > best_val_acc:
                best_val_acc = ev["acc"]
                best_params = jax.tree.map(lambda x: x, self.params)
            lr = plateau.step(ev["loss"])
            if stopper.update(ev["loss"], epoch):
                break
        total = time.perf_counter() - t_start
        self.params = best_params
        test = self.evaluate(self.graph.test_ids)
        n_epochs = len(history)
        return TrainResult(
            policy=self.policy.describe(),
            val_acc=best_val_acc,
            test_acc=test["acc"],
            epochs_to_converge=stopper.best_epoch + 1
            if stopper.best_epoch >= 0 else n_epochs,
            per_epoch_time_s=float(np.mean([h.epoch_time_s
                                            for h in history])),
            total_time_s=total,
            mean_unique_nodes=float(np.mean([h.mean_unique_nodes
                                             for h in history])),
            feature_bytes_per_batch=float(np.mean(
                [h.mean_unique_nodes for h in history]))
            * self.graph.feat_dim * 4,
            caps=self.caps,
            history=history,
        )


def train_once(graph: Graph, cfg: GNNConfig, policy: CommRandPolicy,
               tcfg: Optional[TrainConfig] = None, seed: int = 0,
               verbose: bool = False) -> TrainResult:
    tcfg = tcfg or TrainConfig()
    return GNNTrainer(graph, cfg, tcfg, policy,
                      seed=seed).warmup().fit(verbose)
