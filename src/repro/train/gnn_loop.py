"""GNN mini-batch training loop — mirrors the paper's methodology (§5):
AdamW(lr=1e-3, wd=5e-4), batch 1024, fanout 10 per hop, up to 100 epochs,
early stopping on val loss (patience 6), ReduceLROnPlateau (patience 3),
metrics: final val acc, per-epoch time, epochs-to-converge, total time, and
the Fig-6 working-set metric (mean unique input nodes / feature bytes).

Batch construction goes through `repro.batching` end to end: the trainer
consumes a `BatchStream` whose `Cursor(epoch, pos)` is saved in every
checkpoint, so interrupted GNN runs resume bit-exactly (the contract the LM
trainer has always had). Dropout keys derive from the same (seed, epoch,
pos) as the stream, and `fit()`'s scheduler state (lr, plateau/early-stop
counters, best-so-far weights) is checkpointed alongside the cursor, so a
resumed run replays the same training trajectory.

Feature cache: `cache=` (a `repro.featcache.CachePlan`, admission-policy
name, `DynamicCacheState`, or `"dynamic[:admission]"`) routes every
layer-0 feature read through the device-resident cache (`gather_cached`)
— a pure read-path optimization (loss trajectory is bit-identical) whose
measured hit rate lands in each `EpochMetrics` via a `HitRateMeter`,
turning the paper's §6.5 cache-locality claim into a number this trainer
reports. With DYNAMIC admission the cache is trainer-carried mutable
state: every TRAIN step folds the extended device counters into the CLOCK
reference bits / candidate frequencies (`dynamic.ref_updates`, inside the
jitted step, reassembled host-side so the (C, F) rows are never copied),
and at every epoch boundary — in `run_epoch` AND when `train_steps`
crosses epochs — `dynamic.refill` swaps cold slots for hot missed rows.
The evolving state is checkpointed alongside the weights (plus the
boundary bookkeeping in `extra`), so interrupted dynamic-cache runs
resume with a bit-identical loss trajectory AND cache state. Evaluation
reads through the cache but never feeds the counters.

Guarded execution (`repro.resilience`): the jitted train step checks the
loss and every grad leaf for finiteness ON DEVICE and applies no update
on a non-finite step (a `jnp.where` select — no extra host sync; with
`poison=1.0` the guard is a bit-exact no-op). A device-resident
consecutive-skip counter rides through the step; with
`GNNTrainer(guard=GuardConfig(...))` the trainer syncs it every
`check_every` steps (and always at flush/checkpoint boundaries), and
past `max_consecutive_skips` escalates: `resilient_step` restores the
newest VALID checkpoint (`restore_latest` falls back across corrupt
ones) and replays — bit-exact, because batches, dropout keys and cache
state are pure functions of the checkpointed cursor. Skips, rollbacks
and checkpoint fallbacks are metered in a
`train.monitor.ResilienceMeter`. The dynamic cache additionally passes a
residency integrity check at every refill; on failure the trainer drops
to the uncached gather and keeps training (cache rows are bit-copies, so
the loss trajectory is unaffected), surfacing the event through the
`HitRateMeter` trajectory and the resilience meter.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import featcache, sampling
from repro.dist import gnn as dist_gnn
from repro.featcache import dynamic as featcache_dynamic
from repro.featcache.dynamic import DynamicCacheState
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsHub
from repro.batching import (BatchStream, CapsCalibrator, Cursor, as_policy,
                            eval_batches, make_policy)
from repro.configs.base import GNNConfig, TrainConfig
from repro.core import minibatch as mb
from repro.graphs.csr import DeviceGraph, Graph
from repro.kernels.gather_cached.ops import cache_stats
from repro.models.gnn.models import apply_gnn, init_gnn
from repro.optim import adamw
from repro.optim.schedule import EarlyStopping, ReduceLROnPlateau
from repro.resilience import faults
from repro.resilience.guard import as_guard
from repro.train import checkpoint as ckpt
from repro.train.losses import accuracy, gnn_softmax_ce
from repro.train.monitor import (HitRateMeter, ResilienceMeter, StepFailure,
                                 StragglerMonitor, resilient_step)


@dataclass
class EpochMetrics:
    epoch: int
    train_loss: float
    val_loss: float
    val_acc: float
    epoch_time_s: float
    mean_unique_nodes: float
    cache_hit_rate: float = 0.0     # measured (repro.featcache); 0 = no cache
    cache_refills: int = 0          # dynamic-CLOCK rows admitted (churn)
    straggler_fraction: float = 0.0  # slow-step fraction of THIS epoch


@dataclass
class TrainResult:
    policy: str
    val_acc: float                  # at best epoch
    test_acc: float
    epochs_to_converge: int
    per_epoch_time_s: float
    total_time_s: float
    mean_unique_nodes: float
    feature_bytes_per_batch: float
    caps: tuple
    history: List[EpochMetrics] = field(default_factory=list)
    cache: str = ""                 # cache describe(), "" = uncached
    cache_hit_rate: float = 0.0     # measured over the whole run
    cache_refills: int = 0          # total dynamic-CLOCK churn of the run
    straggler_fraction: float = 0.0  # slow-step fraction of the whole run


def _batch_cache_stats(cache, batch: mb.MiniBatch):
    """Device (hits, misses) for this batch's layer-0 reads — the same
    counters `gather_cached` computes inside `apply_gnn`."""
    if cache is None:
        return jnp.int32(0), jnp.int32(0)
    return cache_stats(cache.pos, batch.node_ids, cache.pos.shape[0])


def _make_steps(cfg: GNNConfig, tcfg: TrainConfig):
    @functools.partial(jax.jit, static_argnames=())
    def train_step(params, opt_state, batch: mb.MiniBatch, feats, degrees,
                   lr, key, cache, poison, skips):
        def loss_fn(p):
            # no (cap_L, F) pre-gather: layer 0 reads feature rows straight
            # from the global matrix through the fused gather-agg path —
            # or, with a cache plan, through the two-level gather_cached
            logits = apply_gnn(cfg, p, batch, feats, degrees, train=True,
                               dropout_key=key, feats_global=True,
                               cache=cache)
            # `poison` is 1.0 in normal runs (multiplying by 1.0 is a
            # bitwise no-op in IEEE) and NaN when the `step_nonfinite`
            # chaos site is armed: loss AND every grad go non-finite, so
            # the guard below must catch it
            return gnn_softmax_ce(logits, batch.labels,
                                  batch.label_mask.astype(jnp.float32)) \
                * poison

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # guarded execution, folded into the step (zero extra host syncs):
        # a non-finite loss or any non-finite grad leaf means this batch
        # applies NO update — params/opt are kept via a where-select and
        # the device-resident consecutive-skip counter increments
        ok = jnp.isfinite(loss)
        for g in jax.tree.leaves(grads):
            ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(g)))
        new_params, new_opt = adamw.update(
            grads, opt_state, params, lr=lr,
            weight_decay=tcfg.weight_decay)

        def keep(new, old):
            return jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, old)

        new_params, new_opt = keep(new_params, params), \
            keep(new_opt, opt_state)
        skips = jnp.where(ok, jnp.int32(0), skips + jnp.int32(1))
        hits, misses = _batch_cache_stats(cache, batch)
        # dynamic CLOCK admission: fold this batch's reads into the
        # reference bits / candidate frequencies ON DEVICE; only the three
        # accumulator arrays come back (the (C, F) rows are never copied).
        # NOT gated on `ok`: a skipped batch still touched its rows, and
        # replayed reads after a rollback refold identically anyway.
        refs = (featcache_dynamic.ref_updates(cache, batch.node_ids)
                if isinstance(cache, DynamicCacheState) else None)
        return new_params, new_opt, loss, ok, skips, hits, misses, refs

    @jax.jit
    def eval_step(params, batch: mb.MiniBatch, feats, degrees, cache):
        logits = apply_gnn(cfg, params, batch, feats, degrees, train=False,
                           feats_global=True, cache=cache)
        m = batch.label_mask.astype(jnp.float32)
        return (gnn_softmax_ce(logits, batch.labels, m),
                accuracy(logits, batch.labels, m), m.sum())

    return train_step, eval_step


class GNNTrainer:
    """One (graph, model, policy) training run over a `BatchStream`."""

    def __init__(self, graph: Graph, cfg: GNNConfig, tcfg: TrainConfig,
                 policy, caps=None, eval_caps=None, seed: int = 0,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 calibrator: Optional[CapsCalibrator] = None,
                 cache=None, cache_capacity: Optional[int] = None,
                 cache_frac: float = 0.2, pipeline: str = "sync",
                 guard=None, mesh=None):
        self.graph = graph
        self.cfg = cfg
        self.tcfg = tcfg
        self.policy = as_policy(policy)
        self.seed = seed
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.g = DeviceGraph.from_graph(graph)
        self.feats = jnp.asarray(graph.features)
        self.labels = jnp.asarray(graph.labels)
        self.degrees = self.g.degrees
        self.fanouts = tuple(cfg.fanout[:cfg.num_layers])
        # the policy binds its neighbor sampler (repro.sampling); caps are
        # calibrated — and disk-cached — per (policy, sampler) pair
        self.sampler = sampling.for_policy(self.policy)
        cal = calibrator or CapsCalibrator(seed=seed)
        self.caps = caps or cal.caps_for(
            graph, self.policy, tcfg.batch_size, self.fanouts)
        # eval always uses the uniform policy (identical across compared
        # policies) — calibrate once with p=0.5
        self.eval_policy = make_policy("rand")
        self.eval_sampler = sampling.for_policy(self.eval_policy)
        eval_cal = calibrator or CapsCalibrator(seed=seed + 1)
        self.eval_caps = eval_caps or eval_cal.caps_for(
            graph, self.eval_policy, tcfg.batch_size, self.fanouts)
        self.train_step, self.eval_step = _make_steps(cfg, tcfg)
        self.params = init_gnn(cfg, jax.random.key(seed))
        self.opt_state = adamw.init(self.params)
        # `cache` is a CachePlan / DynamicCacheState / admission name /
        # "dynamic[:admission]" (built here against THIS policy's access
        # distribution); it rides on the stream and every step gathers
        # layer-0 features through it
        self.cache = featcache.as_cache(
            cache, graph, capacity=cache_capacity, frac=cache_frac,
            policy=self.policy, batch_size=tcfg.batch_size,
            fanouts=self.fanouts, seed=seed)
        # one metrics registry for the whole run (repro.obs): the three
        # meters below mirror every mutation into it, and `hub.export()`
        # is the versioned runtime-metrics artifact of this trainer
        self.hub = MetricsHub()
        self.cache_meter = HitRateMeter(hub=self.hub)
        self._pending_stats = []      # device counters, synced per epoch
        # per-step dispatch-time outlier tracking (host wall clock only —
        # no sync; observed on every `_train_one` dispatch), surfaced as
        # `EpochMetrics.straggler_fraction` + the "straggler/*" hub series
        self.straggler = StragglerMonitor(hub=self.hub)
        # sync-free device step timing (repro.obs): per-step dispatch
        # timestamps accumulate and flush into one "device_steps" trace
        # span ONLY at the existing epoch/n-step boundary drains
        self._dev_timer = obs_trace.DeviceStepTimer()
        # guarded execution (repro.resilience): None/False disables (the
        # in-jit guard still runs but is never synced or escalated),
        # True = GuardConfig() defaults, or an explicit GuardConfig
        self.guard = as_guard(guard)
        self.guard_meter = ResilienceMeter(hub=self.hub)
        self._skips = jnp.zeros((), jnp.int32)   # device skip counter
        self._skips_host = 0          # last synced value (guard checks)
        self._pending_ok = []         # (ok, step) device flags, per flush
        # pipeline="sync" is the classic BatchStream (host epoch order +
        # single-slot async dispatch); "async" swaps in the depth-2
        # background prefetcher over the fused on-device builder
        # (`repro.pipeline`) — same Cursor semantics, bit-exact batches
        if pipeline not in ("sync", "async"):
            raise ValueError(
                f"pipeline must be 'sync' or 'async', got {pipeline!r}")
        # mesh=None is the classic single-device path. A 1-D ("shard",)
        # Mesh switches on data-parallel training (repro.dist.gnn): the
        # feature matrix is community-partitioned across the mesh, the
        # stream deals each global root batch as per-replica slices, and
        # the jitted step runs under shard_map with psum'd grads. The
        # global epoch order, cursor and checkpoints are unchanged — a
        # 1-replica mesh is bit-identical to mesh=None.
        self.mesh = mesh
        self.splan = None
        self._hplan = None
        self._hplan_epoch = -1
        self._step_cache = {}           # HaloPlan -> jitted sharded step
        self._remitter = None           # per-replica trace re-emitter
        stream_kwargs = {}
        if mesh is not None:
            if pipeline != "sync":
                raise ValueError(
                    "mesh training requires pipeline='sync' (the async "
                    "prefetcher is single-device for now)")
            if isinstance(self.cache, DynamicCacheState):
                raise ValueError(
                    "mesh training supports a static CachePlan only; "
                    "dynamic CLOCK admission is single-device for now")
            d = mesh.shape[dist_gnn.AXIS]
            if tcfg.batch_size % d:
                raise ValueError(
                    f"batch_size {tcfg.batch_size} not divisible by the "
                    f"{d}-replica mesh")
            self.splan = dist_gnn.community_shard_plan(graph, d)
            stream_cls = dist_gnn.ShardedBatchStream
            stream_kwargs.update(mesh=mesh, plan=self.splan)
        elif pipeline == "async":
            from repro.pipeline import AsyncBatchStream
            stream_cls = AsyncBatchStream
            # watchdog restarts surface in THIS trainer's resilience meter
            stream_kwargs["meter"] = self.guard_meter
        else:
            stream_cls = BatchStream
        self.pipeline = pipeline
        self.stream = stream_cls(
            graph, self.policy, tcfg.batch_size, self.fanouts, self.caps,
            seed=seed, device_graph=self.g, labels=self.labels,
            cache=self.cache, **stream_kwargs)
        if mesh is not None:
            # model/opt state is replicated; features live sharded with
            # the replicated id->slot map riding alongside them
            self._train_feats = {
                "local": self.splan.shard_features(graph.features, mesh),
                "pos": self.splan.device_pos(mesh)}
            self.params = dist_gnn.replicate(self.params, mesh)
            self.opt_state = dist_gnn.replicate(self.opt_state, mesh)
            self._skips = dist_gnn.replicate(self._skips, mesh)
            if self.cache is not None:
                self._set_cache(dist_gnn.replicate(self.cache, mesh))
            self.train_step = self._sharded_train_step
        else:
            self._train_feats = self.feats
        # epoch whose boundary refill is still pending (dynamic cache);
        # travels in checkpoint `extra` so resume never double-refills
        self._cache_epoch = self.stream.cursor.epoch
        self.global_step = 0
        self._best_params = None      # best-val weights seen by fit()
        self._fit_state = None        # lr / plateau / early-stop counters
        if ckpt_dir:
            self._try_resume()

    # -- checkpoint/resume (cursor + fit state travel with the weights) -----
    def _state(self):
        best = self._best_params if self._best_params is not None \
            else self.params
        state = {"params": self.params, "opt": self.opt_state, "best": best}
        if isinstance(self.cache, DynamicCacheState):
            # the evolving CLOCK state is training state: rows, residency,
            # reference bits, accumulators and hand all resume bit-exactly
            state["cache"] = self.cache
        return state

    def save(self) -> None:
        if not self.ckpt_dir:
            return
        ckpt.save(self.ckpt_dir, self.global_step, self._state(),
                  extra={"cursor": self.stream.cursor.state(),
                         "fit": self._fit_state,
                         "cache_epoch": self._cache_epoch})

    def _on_corrupt_ckpt(self, step: int, err: Exception) -> None:
        """`restore_latest` fallback hook: meter each corrupt/partial
        checkpoint skipped on the way to the newest valid one."""
        self.guard_meter.note("ckpt_fallbacks", ckpt_step=step,
                              error=str(err))

    def _apply_restored(self, step: int, tree, extra) -> None:
        """Install a restored checkpoint as the live training state
        (shared by startup resume and guard rollback)."""
        self.params, self.opt_state = tree["params"], tree["opt"]
        self._best_params = tree["best"]
        self.global_step = step
        self.stream.cursor = Cursor.from_state(extra["cursor"])
        self._fit_state = extra.get("fit")
        if "cache" in tree:
            self._set_cache(tree["cache"])
        self._cache_epoch = int(extra.get("cache_epoch",
                                          self.stream.cursor.epoch))

    def _shardings(self):
        """Checkpoint-restore shardings: replicated-on-mesh leaves in
        mesh mode (so a sharded-run resume lands its state back on the
        mesh, not on one device), None otherwise."""
        if self.mesh is None:
            return None
        return dist_gnn.state_shardings(self._state(), self.mesh)

    def _try_resume(self) -> None:
        step, tree, extra = ckpt.restore_latest(
            self.ckpt_dir, self._state(), shardings=self._shardings(),
            on_corrupt=self._on_corrupt_ckpt)
        if step is None:
            return
        self._apply_restored(step, tree, extra)

    # -- sharded step (repro.dist.gnn) --------------------------------------
    def _sharded_step_for(self, epoch: int):
        """The jitted sharded train step for `epoch`. The halo exchange
        budget is re-planned at every epoch boundary from that epoch's
        root order; the compiled step is cached per `HaloPlan`, so
        epochs whose plans agree (the steady state — COMM-RAND's orders
        shuffle blocks, not community membership) reuse one executable
        and never retrace (the recompile-stability contract
        `analysis.jaxpr_audit.audit_sharded_step` gates)."""
        if self._hplan_epoch != epoch:
            self._hplan = dist_gnn.plan_halo(
                self.splan, self.graph, self.fanouts, self.caps[-1],
                self.stream.root_batches(epoch))
            self._hplan_epoch = epoch
            self._remitter = dist_gnn.ReplicaTraceEmitter(
                self.splan.n_shards, self._hplan, self.caps[-1],
                self.graph.feat_dim)
        step = self._step_cache.get(self._hplan)
        if step is None:
            step = self._step_cache[self._hplan] = \
                dist_gnn.make_sharded_steps(
                    self.cfg, self.tcfg, self.mesh, self.splan,
                    self._hplan)
        return step

    def _sharded_train_step(self, params, opt_state, batch, feats, degrees,
                            lr, key, cache, poison, skips):
        return self._sharded_step_for(self.stream.cursor.epoch)(
            params, opt_state, batch, feats, degrees, lr, key, cache,
            poison, skips)

    # -- batch building -----------------------------------------------------
    def _dropout_key(self):
        """Derived from the batch the stream just yielded (cursor already
        advanced), so resumed runs replay identical dropout masks."""
        return jax.random.fold_in(
            self.stream.batch_key(self.stream.cursor.epoch,
                                  self.stream.cursor.pos - 1), 1)

    def warmup(self):
        """Trigger all jit compilations without disturbing training state
        (so per-epoch timings measure steady-state throughput)."""
        saved = (jax.tree.map(lambda x: x, self.params),
                 jax.tree.map(lambda x: x, self.opt_state))
        roots = np.full(self.tcfg.batch_size, -1, np.int64)
        roots[:min(len(self.graph.train_ids), 8)] = \
            self.graph.train_ids[:8]
        if self.mesh is not None:
            # the sharded stream stacks per-replica sub-batches; going
            # through it compiles the same build the epoch will use
            b = self.stream.build(roots, self.stream.cursor.epoch, 0)
        else:
            b = mb.build_batch(jax.random.key(0), self.g,
                               jnp.asarray(roots, jnp.int32), self.labels,
                               self.fanouts, self.caps, self.sampler)
        self.params, self.opt_state, *_ = self.train_step(
            self.params, self.opt_state, b, self._train_feats,
            self.degrees, 0.0, jax.random.key(0), self.cache, 1.0,
            self._skips)
        be = mb.build_batch(jax.random.key(0), self.g,
                            jnp.asarray(roots, jnp.int32), self.labels,
                            self.fanouts, self.eval_caps,
                            self.eval_sampler)
        self.eval_step(self.params, be, self.feats, self.degrees,
                       self.cache)
        self.params, self.opt_state = saved
        return self

    def _set_cache(self, cache) -> None:
        """Replace the carried cache state (and keep the stream's view —
        the plumbing consumers read it back from — in sync)."""
        self.cache = cache
        self.stream.cache = cache

    def _train_one(self, batch: mb.MiniBatch, lr: float):
        t0 = time.perf_counter()
        step0 = self.global_step
        with obs_trace.span("train_step", cat="step", step=step0):
            poison = 1.0
            if faults.fire("step_nonfinite",
                           step=self.global_step) is not None:
                # chaos site: NaN the loss inside the jitted step — python
                # floats are weak-typed scalars, so 1.0 vs nan never
                # retraces
                poison = float("nan")
            self.params, self.opt_state, loss, ok, self._skips, hits, \
                misses, refs = self.train_step(
                    self.params, self.opt_state, batch, self._train_feats,
                    self.degrees, lr, self._dropout_key(), self.cache,
                    poison, self._skips)
            # sync-free device timing: record the dispatch timestamp +
            # the un-synced loss; the accumulated window closes at the
            # NEXT existing boundary drain (epoch flush / n-step sync)
            self._dev_timer.note(loss)
            if self.cache is not None:
                # keep the device counters un-synced: a float()/int()
                # here would serialize away the stream's prefetch overlap
                self._pending_stats.append((hits, misses))
            if self.guard is not None:
                self._pending_ok.append((ok, self.global_step))
            if isinstance(refs, dict):
                # sharded step: the slot carries the per-replica aux
                # payload (loss share / halo drops / cache counters as
                # un-synced (D,) arrays), not dynamic-cache refs — queue
                # it for the per-replica trace re-emission at the epoch
                # boundary drain
                if self._remitter is not None and \
                        obs_trace.current() is not None:
                    self._remitter.note(
                        t0 * 1e6, (time.perf_counter() - t0) * 1e6,
                        step0, refs)
            elif refs is not None:
                self._set_cache(
                    featcache_dynamic.with_refs(self.cache, refs))
            self.global_step += 1
            # a checkpoint due at this step forces a guard sync first: we
            # must NEVER checkpoint mid-skip-burst, or a later rollback to
            # that checkpoint would permanently lose the skipped batches
            # (the replayed trajectory could not bit-match a clean run)
            # analysis: allow[no-host-sync-in-hot-path] -- bool() over host ints/paths (ckpt cadence), no device operand
            due_ckpt = bool(self.ckpt_dir and self.ckpt_every and
                            self.global_step % self.ckpt_every == 0)
            rolled = self._guard_check(force=due_ckpt)
            # refill BEFORE any checkpoint at this step: a boundary
            # checkpoint then carries the post-refill state + advanced
            # _cache_epoch, so a resumed run neither skips nor repeats
            # the refill
            self._maybe_refill()
            if due_ckpt and not rolled and self._skips_host == 0:
                self.save()
        # host dispatch time (never a device sync): a straggler here is a
        # slow HOST — batch starvation, dispatch overhead, rollback work
        self.straggler.observe(time.perf_counter() - t0, step0)
        return loss

    def _maybe_refill(self) -> None:
        """Epoch-boundary CLOCK eviction/refill (dynamic cache only).

        Called after every consumed batch, in `run_epoch` AND
        `train_steps`: the cursor reaching the end of epoch
        `_cache_epoch` triggers exactly one refill per boundary — the one
        point where residency may change, outside all differentiated
        code. Syncs one int (the churn) per epoch."""
        if not isinstance(self.cache, DynamicCacheState):
            return
        c = self.stream.cursor
        at_end = c.pos >= self.stream.num_batches(c.epoch)
        if not (c.epoch > self._cache_epoch or
                (c.epoch == self._cache_epoch and at_end)):
            return
        # cat="sync": the refill's churn count + integrity check round-trip
        # to host. It fires inside the epoch's LAST train step (so the
        # mid-epoch-sync gate sanctions it by construction).
        with obs_trace.span("cache_refill", cat="sync", epoch=c.epoch):
            self._refill_now(c, at_end)

    def _refill_now(self, c, at_end: bool) -> None:
        state, admitted = featcache_dynamic.refill(self.cache, self.feats)
        if not featcache_dynamic.integrity_ok(state):
            # graceful degradation: residency invariants broken (the
            # cache_corrupt chaos site, or a real bug) — drop to the
            # uncached feats_global gather rather than serve rows through
            # a corrupt pos map. Detected HERE, at the refill boundary
            # BEFORE any read goes through the new state, so every loss
            # ever computed came from intact bit-copies of the global
            # rows and the trajectory stays bit-identical to an
            # uncorrupted run.
            self.guard_meter.note("cache_degradations",
                                  step=self.global_step, epoch=c.epoch)
            self.cache_meter.note_degraded(self.global_step)
            self._pending_stats = []    # counters of the dropped state
            self._set_cache(None)
            return
        self._set_cache(state)
        self.cache_meter.observe_refill(admitted)
        self._cache_epoch = c.epoch + 1 if at_end else c.epoch

    def _flush_cache_stats(self) -> None:
        """Sync pending per-batch device flags: cache counters into the
        hit-rate meter, guard ok flags into the resilience meter."""
        if not (self._pending_stats or self._pending_ok):
            return
        with obs_trace.span("stats_flush", cat="sync",
                            n=(len(self._pending_stats) +
                               len(self._pending_ok))):
            for h, m in self._pending_stats:
                self.cache_meter.observe(h, m)
            self._pending_stats = []
            for ok, step in self._pending_ok:
                if not bool(ok):
                    self.guard_meter.note("skipped_steps", step=step)
            self._pending_ok = []

    # -- guarded execution (repro.resilience) -------------------------------
    def _guard_check(self, force: bool = False) -> bool:
        """Sync the device skip counter when due (`check_every` cadence,
        or forced at flush/checkpoint boundaries) and escalate past the
        consecutive-skip budget. Returns True if it rolled back."""
        g = self.guard
        if g is None:
            return False
        if not (force or (g.check_every > 0 and
                          self.global_step % g.check_every == 0)):
            return False
        with obs_trace.span("guard_sync", cat="sync",
                            step=self.global_step):
            # analysis: allow[no-host-sync-in-hot-path] -- THE one guard sync, amortized by check_every cadence (see GuardConfig)
            self._skips_host = int(self._skips)  # the one guard sync
        if self._skips_host <= g.max_consecutive_skips:
            return False
        self._escalate()
        return True

    def _escalate(self) -> None:
        """Consecutive-skip budget blown: roll back to the newest VALID
        checkpoint and replay. Replay is clean for transient causes
        (an armed fault window is behind the invocation counter by the
        time the replayed steps re-fire) and bit-exact because batches,
        dropout keys and cache state are pure functions of the restored
        cursor. Persistent causes re-escalate until `max_rollbacks`,
        then raise StepFailure."""
        self._flush_cache_stats()       # meter the skips we're erasing
        self.guard_meter.note("rollbacks", step=self.global_step,
                              skips=self._skips_host)
        if self.guard_meter.rollbacks > self.guard.max_rollbacks:
            raise StepFailure(
                f"non-finite steps persisted through "
                f"{self.guard.max_rollbacks} rollbacks "
                f"(step {self.global_step})")
        if not self.ckpt_dir:
            raise StepFailure(
                f"{self._skips_host} consecutive non-finite steps at step "
                f"{self.global_step} and no ckpt_dir to roll back to")

        def _restore():
            step, tree, extra = ckpt.restore_latest(
                self.ckpt_dir, self._state(), shardings=self._shardings(),
                on_corrupt=self._on_corrupt_ckpt)
            if step is None:
                raise StepFailure(
                    f"rollback found no valid checkpoint in "
                    f"{self.ckpt_dir}")
            return step, tree, extra

        with obs_trace.span("ckpt_rollback", cat="ckpt",
                            step=self.global_step,
                            skips=self._skips_host):
            (step, tree, extra), _ = resilient_step(
                _restore, max_retries=1, backoff_s=0.05)
            self._apply_restored(step, tree, extra)
        self._skips = jnp.zeros((), jnp.int32)
        self._skips_host = 0
        self._pending_stats = []
        self._pending_ok = []

    def run_epoch(self, lr: float) -> Dict:
        """Consume the remainder of the stream's current epoch (the
        epoch-boundary refill fires inside `_train_one` at the last
        batch, so the dynamic cache is already post-refill on return)."""
        t0 = time.perf_counter()
        e0 = self.stream.cursor.epoch
        mark = self.cache_meter.mark()
        smark = self.straggler.mark()
        losses, uniq = [], []
        # the epoch envelope span is what the trace analyzer's mid-epoch
        # sync gate anchors on: every cat="sync" span starting inside it
        # before the final train step fails `--forbid-mid-epoch-sync`
        with obs_trace.span("epoch", cat="loop", epoch=e0):
            for batch in self.stream.epoch():
                losses.append(self._train_one(batch, lr))
                uniq.append(batch.num_unique)
            if losses:
                with obs_trace.span("epoch_flush", cat="sync", epoch=e0,
                                    n_steps=len(losses)):
                    # analysis: allow[no-host-sync-in-hot-path] -- epoch-boundary flush: one drain per epoch so `time` covers real device work
                    jax.block_until_ready(losses[-1])
                # the device window closes only AFTER the drain above —
                # the timer itself never syncs
                self._dev_timer.flush("epoch")
                if self._remitter is not None:
                    # per-replica Perfetto tracks, reconstructed from the
                    # queued aux (device already drained, so the host
                    # transfers here cost no new sync)
                    self._remitter.flush(obs_trace.current(), e0)
            dt = time.perf_counter() - t0
            self._flush_cache_stats()
            self._guard_check(force=True)  # epoch boundary: exact skips
        self.hub.mark_epoch(e0)
        if not losses:          # resumed exactly on an epoch boundary
            return {"loss": 0.0, "time": dt, "uniq": 0.0,
                    "cache_hit": 0.0, "cache_refill": 0,
                    "straggler": 0.0}
        ep = self.cache_meter.note_epoch(mark) if self.cache is not None \
            else {"hit_rate": 0.0, "refills": 0}
        # analysis: allow[no-host-sync-in-hot-path] -- post-flush metric reduction at the epoch boundary; device is already drained
        return {"loss": float(np.mean([float(l) for l in losses])),
                "time": dt,
                # a sharded batch carries (D,) per-replica unique counts;
                # np.asarray averages them (scalar-safe for mesh=None)
                # analysis: allow[no-host-sync-in-hot-path] -- post-flush metric reduction at the epoch boundary; device is already drained
                "uniq": float(np.mean([np.asarray(u).mean()
                                       for u in uniq])),
                "cache_hit": ep["hit_rate"],
                "cache_refill": ep["refills"],
                "straggler": self.straggler.fraction_since(smark)}

    def train_steps(self, n: int, lr: Optional[float] = None) -> List[float]:
        """Consume exactly `n` batches (crossing epoch boundaries)."""
        lr = self.tcfg.learning_rate if lr is None else lr
        it = iter(self.stream)
        # keep losses on device until the end: a float() per step would
        # sync every batch and serialize away the stream's prefetch overlap
        losses = [self._train_one(next(it), lr) for _ in range(n)]
        self._flush_cache_stats()
        self._guard_check(force=True)
        with obs_trace.span("steps_flush", cat="sync", n=n):
            # analysis: allow[no-host-sync-in-hot-path] -- single batched sync at the END of the n-step run (see comment above: no per-step float)
            out = [float(l) for l in losses]
        self._dev_timer.flush("train_steps")
        if self._remitter is not None:
            self._remitter.flush(obs_trace.current(),
                                 self.stream.cursor.epoch)
        return out

    def evaluate(self, ids: np.ndarray) -> Dict:
        with obs_trace.span("eval", cat="eval", n_ids=len(ids)):
            return self._evaluate(ids)

    def _evaluate(self, ids: np.ndarray) -> Dict:
        tot_l, tot_a, tot_n = 0.0, 0.0, 0.0
        for batch in eval_batches(
                self.graph, ids, self.tcfg.batch_size, self.fanouts,
                self.eval_caps, sampler=self.eval_sampler,
                seed=self.seed + 17,
                device_graph=self.g, labels=self.labels):
            l, a, n = self.eval_step(self.params, batch, self.feats,
                                     self.degrees, self.cache)
            # analysis: allow[no-host-sync-in-hot-path] -- evaluation accumulates on host; eval batches are not prefetch-overlapped
            n = float(n)
            # analysis: allow[no-host-sync-in-hot-path] -- evaluation accumulates on host; eval batches are not prefetch-overlapped
            tot_l += float(l) * n
            # analysis: allow[no-host-sync-in-hot-path] -- evaluation accumulates on host; eval batches are not prefetch-overlapped
            tot_a += float(a) * n
            tot_n += n
        return {"loss": tot_l / max(tot_n, 1), "acc": tot_a / max(tot_n, 1)}

    def fit(self, verbose: bool = False) -> TrainResult:
        stopper = EarlyStopping(self.tcfg.early_stop_patience)
        plateau = ReduceLROnPlateau(self.tcfg.learning_rate,
                                    self.tcfg.plateau_factor,
                                    self.tcfg.plateau_patience)
        history: List[EpochMetrics] = []
        best_val_acc = 0.0
        best_params = self._best_params if self._best_params is not None \
            else self.params
        lr = self.tcfg.learning_rate
        start_epoch = 0
        if self._fit_state:                   # resumed mid-training
            fs = self._fit_state
            lr, start_epoch = fs["lr"], fs["epoch"]
            best_val_acc = fs["best_val_acc"]
            plateau.lr, plateau.best, plateau.bad = fs["plateau"]
            stopper.best, stopper.bad, stopper.best_epoch = fs["stopper"]
        if stopper.bad >= stopper.patience:
            # checkpoint came from an ALREADY-FINISHED (early-stopped) run:
            # don't train further from best_params
            start_epoch = self.tcfg.max_epochs
        t_start = time.perf_counter()
        for epoch in range(start_epoch, self.tcfg.max_epochs):
            em = self.run_epoch(lr)
            ev = self.evaluate(self.graph.val_ids)
            history.append(EpochMetrics(epoch, em["loss"], ev["loss"],
                                        ev["acc"], em["time"], em["uniq"],
                                        em["cache_hit"],
                                        em["cache_refill"],
                                        em["straggler"]))
            if verbose:
                print(f"  epoch {epoch:3d} loss={em['loss']:.4f} "
                      f"val={ev['acc']:.4f} t={em['time']:.2f}s "
                      f"uniq={em['uniq']:.0f} "
                      f"cache_hit={em['cache_hit']:.3f} "
                      f"refill={em['cache_refill']}")
            if ev["acc"] > best_val_acc:
                best_val_acc = ev["acc"]
                best_params = jax.tree.map(lambda x: x, self.params)
            lr = plateau.step(ev["loss"])
            stop = stopper.update(ev["loss"], epoch)
            self._best_params = best_params
            self._fit_state = {
                "lr": lr, "epoch": epoch + 1, "best_val_acc": best_val_acc,
                "plateau": [plateau.lr, plateau.best, plateau.bad],
                "stopper": [stopper.best, stopper.bad, stopper.best_epoch],
            }
            if stop:
                break
        total = time.perf_counter() - t_start
        self.params = best_params
        if self.ckpt_dir:
            self.save()
        test = self.evaluate(self.graph.test_ids)
        n_epochs = len(history)

        def _mean(xs):                # empty when resuming a finished run
            return float(np.mean(xs)) if xs else 0.0

        return TrainResult(
            policy=self.policy.describe(),
            val_acc=best_val_acc,
            test_acc=test["acc"],
            epochs_to_converge=stopper.best_epoch + 1
            if stopper.best_epoch >= 0 else n_epochs,
            per_epoch_time_s=_mean([h.epoch_time_s for h in history]),
            total_time_s=total,
            mean_unique_nodes=_mean([h.mean_unique_nodes for h in history]),
            feature_bytes_per_batch=_mean([h.mean_unique_nodes
                                           for h in history])
            * self.graph.feat_dim * 4,
            caps=self.caps,
            history=history,
            cache=self.cache.describe() if self.cache is not None else "",
            cache_hit_rate=self.cache_meter.hit_rate,
            cache_refills=self.cache_meter.refills,
            straggler_fraction=self.straggler.straggler_fraction,
        )


def train_once(graph: Graph, cfg: GNNConfig, policy,
               tcfg: Optional[TrainConfig] = None, seed: int = 0,
               verbose: bool = False,
               calibrator: Optional[CapsCalibrator] = None,
               cache=None, pipeline: str = "sync",
               guard=None) -> TrainResult:
    tcfg = tcfg or TrainConfig()
    return GNNTrainer(graph, cfg, tcfg, policy, seed=seed,
                      calibrator=calibrator, cache=cache,
                      pipeline=pipeline, guard=guard).warmup().fit(verbose)
