"""Prior-work baselines for the paper's §6.3 comparison table and the §2
full-batch-vs-mini-batch motivation:

  - ClusterGCN [14]: batches = random unions of graph partitions
    (communities here, as the partitioner); the subgraph is the FULL induced
    subgraph, computed for ALL its nodes — per-epoch cost is invariant to the
    training-set size (paper Fig 8).
  - LABOR-lite [9]: structure-agnostic variance-reduced neighbor sampling —
    neighbors are chosen by shared per-node hash randomness so overlapping
    neighborhoods pick the SAME neighbors, shrinking the unique footprint
    without community info.
  - full-batch: one gradient step per epoch on the whole graph.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.batching.policy import ClusterGCNPolicy
from repro.configs.base import GNNConfig, TrainConfig
from repro.core import minibatch as mb
from repro.graphs.csr import Graph
from repro.models.gnn.fullgraph import SubgraphBatch, sage_subgraph_apply
from repro.models.gnn.models import init_gnn
from repro.optim import adamw
from repro.train.losses import accuracy, gnn_softmax_ce


# ---------------------------------------------------------------------------
# ClusterGCN
# ---------------------------------------------------------------------------
def clustergcn_batches(graph: Graph, parts_per_batch: int,
                       rng: np.random.Generator) -> List[np.ndarray]:
    """Random unions of `parts_per_batch` communities (one epoch) — the
    registered `repro.batching` "clustergcn" policy's node grouping."""
    pol = ClusterGCNPolicy(parts_per_batch=parts_per_batch)
    return pol.member_groups(graph.communities, rng)


def induced_subgraph(graph: Graph, nodes: np.ndarray, cap_n: int,
                     cap_e: int) -> SubgraphBatch:
    pos = np.full(graph.num_nodes, -1, np.int64)
    nodes = nodes[:cap_n]
    pos[nodes] = np.arange(len(nodes))
    srcs, dsts = [], []
    for i, u in enumerate(nodes):
        nbr = graph.indices[graph.indptr[u]:graph.indptr[u + 1]]
        p = pos[nbr]
        ok = p >= 0
        srcs.append(p[ok])
        dsts.append(np.full(ok.sum(), i))
    es = np.concatenate(srcs)[:cap_e] if srcs else np.zeros(0, np.int64)
    ed = np.concatenate(dsts)[:cap_e] if dsts else np.zeros(0, np.int64)
    n_pad, e_pad = cap_n - len(nodes), cap_e - len(es)
    train_set = np.zeros(graph.num_nodes, bool)
    train_set[graph.train_ids] = True
    return SubgraphBatch(
        nodes=jnp.asarray(np.pad(nodes, (0, n_pad),
                                 constant_values=graph.num_nodes), jnp.int32),
        node_mask=jnp.asarray(np.pad(np.ones(len(nodes), bool),
                                     (0, n_pad))),
        edge_src=jnp.asarray(np.pad(es, (0, e_pad)), jnp.int32),
        edge_dst=jnp.asarray(np.pad(ed, (0, e_pad)), jnp.int32),
        edge_mask=jnp.asarray(np.pad(np.ones(len(es), bool), (0, e_pad))),
        labels=jnp.asarray(np.pad(graph.labels[nodes], (0, n_pad)),
                           jnp.int32),
        loss_mask=jnp.asarray(np.pad(train_set[nodes], (0, n_pad))),
    )


def train_clustergcn(graph: Graph, cfg: GNNConfig, tcfg: TrainConfig,
                     parts_per_batch: int = 2, seed: int = 0,
                     epochs: int = None):
    """Returns dict with per-epoch time / val acc (paper Table 4 / Fig 8)."""
    rng = np.random.default_rng((seed, 0))  # salt 0: legacy stream slot
    params = init_gnn(cfg, jax.random.key(seed))
    opt = adamw.init(params)
    feats = jnp.asarray(graph.features)
    # static caps from the largest community union
    sizes = np.bincount(graph.communities)
    cap_n = int(np.sort(sizes)[-parts_per_batch:].sum() * 1.3) + 64
    deg = graph.degrees()
    cap_e = int(cap_n * max(deg.mean() * 2, 8))

    @jax.jit
    def step(params, opt, batch: SubgraphBatch, key):
        def loss_fn(p):
            x = feats[jnp.minimum(batch.nodes, feats.shape[0] - 1)]
            logits = sage_subgraph_apply(cfg, p, batch, x, train=True,
                                         dropout_key=key)
            return gnn_softmax_ce(logits, batch.labels,
                                  batch.loss_mask.astype(jnp.float32))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw.update(grads, opt, params,
                                   lr=tcfg.learning_rate,
                                   weight_decay=tcfg.weight_decay)
        return params, opt, loss

    @jax.jit
    def eval_step(params, batch: SubgraphBatch, mask):
        x = feats[jnp.minimum(batch.nodes, feats.shape[0] - 1)]
        logits = sage_subgraph_apply(cfg, params, batch, x)
        return accuracy(logits, batch.labels, mask)

    key = jax.random.key(seed)
    times, losses = [], []
    n_ep = epochs or tcfg.max_epochs
    for ep in range(n_ep):
        t0 = time.perf_counter()
        for part in clustergcn_batches(graph, parts_per_batch, rng):
            batch = induced_subgraph(graph, part, cap_n, cap_e)
            key, k = jax.random.split(key)
            params, opt, loss = step(params, opt, batch, k)
        jax.block_until_ready(loss)
        times.append(time.perf_counter() - t0)
        losses.append(float(loss))
    # validation on induced full graph in community chunks
    val_set = np.zeros(graph.num_nodes, bool)
    val_set[graph.val_ids] = True
    accs, ns = [], []
    for part in clustergcn_batches(graph, parts_per_batch, rng):
        batch = induced_subgraph(graph, part, cap_n, cap_e)
        vm = val_set[np.asarray(batch.nodes.clip(0, graph.num_nodes - 1))]
        vm &= np.asarray(batch.node_mask)
        if vm.sum() == 0:
            continue
        accs.append(float(eval_step(params, batch,
                                    jnp.asarray(vm, jnp.float32))))
        ns.append(vm.sum())
    val = float(np.average(accs, weights=ns)) if accs else 0.0
    return {"per_epoch_time_s": float(np.mean(times)), "val_acc": val,
            "loss": losses[-1]}


# ---------------------------------------------------------------------------
# full-batch baseline (paper §2)
# ---------------------------------------------------------------------------
def train_fullbatch(graph: Graph, cfg: GNNConfig, tcfg: TrainConfig,
                    seed: int = 0, epochs: int = None):
    cap_n = graph.num_nodes + 1
    cap_e = graph.num_edges + 1
    batch = induced_subgraph(graph, np.arange(graph.num_nodes), cap_n, cap_e)
    params = init_gnn(cfg, jax.random.key(seed))
    opt = adamw.init(params)
    feats = jnp.asarray(graph.features)
    val_set = np.zeros(graph.num_nodes, bool)
    val_set[graph.val_ids] = True
    val_mask = jnp.asarray(np.pad(val_set, (0, 1)), jnp.float32)

    @jax.jit
    def step(params, opt, key):
        def loss_fn(p):
            x = feats[jnp.minimum(batch.nodes, feats.shape[0] - 1)]
            logits = sage_subgraph_apply(cfg, p, batch, x, train=True,
                                         dropout_key=key)
            return gnn_softmax_ce(logits, batch.labels,
                                  batch.loss_mask.astype(jnp.float32))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw.update(grads, opt, params,
                                   lr=tcfg.learning_rate,
                                   weight_decay=tcfg.weight_decay)
        return params, opt, loss

    @jax.jit
    def val_acc(params):
        x = feats[jnp.minimum(batch.nodes, feats.shape[0] - 1)]
        logits = sage_subgraph_apply(cfg, params, batch, x)
        return accuracy(logits, batch.labels, val_mask)

    key = jax.random.key(seed)
    times, accs = [], []
    for ep in range(epochs or tcfg.max_epochs):
        t0 = time.perf_counter()
        key, k = jax.random.split(key)
        params, opt, loss = step(params, opt, k)
        jax.block_until_ready(loss)
        times.append(time.perf_counter() - t0)
        accs.append(float(val_acc(params)))
    return {"per_epoch_time_s": float(np.mean(times)),
            "val_acc_curve": accs, "val_acc": accs[-1]}


# ---------------------------------------------------------------------------
# LABOR-lite: shared-randomness neighbor sampling (structure-agnostic)
# ---------------------------------------------------------------------------
def labor_lite_epoch_footprint(graph: Graph, batches: np.ndarray,
                               fanouts, seed: int = 0):
    """Unique-footprint comparison: neighbors picked by the globally-shared
    per-node hash ranks (LABOR's dependent sampling), no community info.
    Returns mean unique input nodes per batch."""
    rng = np.random.default_rng((seed, 0))  # salt 0: legacy stream slot
    rank = rng.random(graph.num_nodes)        # shared randomness
    sizes = []
    for b in batches:
        level = np.unique(b[b >= 0])
        for r in fanouts:
            nxt = [level]
            for u in level:
                nbr = graph.indices[graph.indptr[u]:graph.indptr[u + 1]]
                if len(nbr) == 0:
                    continue
                if len(nbr) > r:
                    nbr = nbr[np.argpartition(rank[nbr], r)[:r]]
                nxt.append(nbr)
            level = np.unique(np.concatenate(nxt))
        sizes.append(len(level))
    return float(np.mean(sizes))
