"""LM train/serve step factories: jit-compiled, mesh-aware, donation-correct.

`make_train_step` builds the full fused step: forward (remat scan, chunked
CE) -> backward -> grad clip -> AdamW -> new params/opt. With a mesh, params
get FSDPxTP shardings and the step is lowered with explicit in/out shardings
(this is the function the multi-pod dry-run lowers).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.dist import sharding as shd
from repro.models.lm import transformer
from repro.optim import adamw
from repro.train.losses import chunked_cross_entropy


def _with_mesh_ctx(mesh, fn, strategy: str = None):
    """Make `shd` activation constraints active while tracing `fn`."""
    @functools.wraps(fn)
    def wrapped(*a, **k):
        with shd.use_mesh(mesh, strategy):
            return fn(*a, **k)
    return wrapped


def loss_fn(cfg: ModelConfig, params, batch, remat=True):
    hidden, aux = transformer.apply(cfg, params, batch, remat=remat)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    mask = batch.get("mask")
    ce = chunked_cross_entropy(hidden, head.astype(hidden.dtype),
                               batch["labels"], mask)
    return ce + aux, (ce, aux)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    mesh=None, lr: float = None):
    """Returns (step_fn, shardings dict). step(params, opt, batch)->(params,
    opt, metrics)."""
    base_lr = lr if lr is not None else tcfg.learning_rate

    def step(params, opt_state, batch):
        def lf(p):
            n_micro = tcfg.microbatches
            if n_micro <= 1:
                return loss_fn(cfg, p, batch, tcfg.remat)
            # gradient-accumulation microbatching: split batch on dim 0
            def mb(i):
                sub = jax.tree.map(
                    lambda x: x.reshape(n_micro, -1, *x.shape[1:])[i], batch)
                return loss_fn(cfg, p, sub, tcfg.remat)
            tot, (ce0, aux0) = mb(0)
            for i in range(1, n_micro):
                t, _ = mb(i)
                tot = tot + t
            return tot / n_micro, (ce0, aux0)

        (loss, (ce, aux)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
        new_params, new_opt = adamw.update(
            grads, opt_state, params, lr=base_lr,
            weight_decay=tcfg.weight_decay)
        metrics = {"loss": loss, "ce": ce, "aux": aux, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1)), None

    aparams = transformer.abstract_params(cfg)
    pspec = shd.param_shardings(aparams, mesh)
    ospec = {"m": pspec, "v": pspec,
             "count": NamedSharding(mesh, P())}
    mspec = NamedSharding(mesh, P())
    step = _with_mesh_ctx(mesh, step, "fsdp")   # train strategy
    jitted = jax.jit(
        step,
        donate_argnums=(0, 1),
        out_shardings=(pspec, ospec,
                       {k: mspec for k in ("loss", "ce", "aux", "grad_norm")}),
    )
    return jitted, {"params": pspec, "opt": ospec}


def make_eval_step(cfg: ModelConfig, mesh=None):
    def step(params, batch):
        loss, (ce, aux) = loss_fn(cfg, params, batch, remat=False)
        return {"loss": loss, "ce": ce}
    if mesh is not None:
        step = _with_mesh_ctx(mesh, step)
    return jax.jit(step)


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, mesh=None):
    def step(params, batch):
        return transformer.prefill(cfg, params, batch)
    if mesh is not None:
        step = _with_mesh_ctx(mesh, step, "tp_sp")
    return jax.jit(step)


def make_decode_step(cfg: ModelConfig, mesh=None):
    def step(params, cache, tokens, pos):
        return transformer.decode_step(cfg, params, cache, tokens, pos)
    if mesh is not None:
        step = _with_mesh_ctx(mesh, step, "tp_sp")
        return jax.jit(step, donate_argnums=(1,))
    return jax.jit(step, donate_argnums=(1,))
