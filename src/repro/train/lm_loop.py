"""Fault-tolerant LM training loop: checkpoint/resume, retry, straggler
monitoring, optional int8 grad compression, elastic mesh restart.

This is the driver `examples/train_lm.py` and the fault-tolerance tests use;
the pod-scale variant differs only in the mesh passed to the step factory.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import Cursor, LMStream
from repro.dist import sharding as shd
from repro.models.lm import transformer
from repro.optim import adamw
from repro.optim.compression import (compress_decompress,
                                     init_error_feedback)
from repro.train import checkpoint as ckpt
from repro.train.monitor import StragglerMonitor, resilient_step
from repro.train.train_step import loss_fn as lm_loss_fn


def make_ft_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
    """Like train_step but with optional error-feedback grad compression
    (cross-pod all-reduce payload model)."""

    def step(params, opt_state, err, batch, lr):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            lambda p: lm_loss_fn(cfg, p, batch, tcfg.remat),
            has_aux=True)(params)
        if tcfg.grad_compression:
            grads, err = compress_decompress(grads, err)
        grads, gnorm = adamw.clip_by_global_norm(grads, tcfg.grad_clip)
        params, opt_state = adamw.update(grads, opt_state, params, lr=lr,
                                         weight_decay=tcfg.weight_decay)
        return params, opt_state, err, {"loss": loss, "ce": ce,
                                        "grad_norm": gnorm}

    if mesh is not None:
        from repro.train.train_step import _with_mesh_ctx
        step = _with_mesh_ctx(mesh, step)
    return jax.jit(step, donate_argnums=(0, 1, 2))


class LMTrainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, stream: LMStream,
                 ckpt_dir: Optional[str] = None, mesh=None,
                 ckpt_every: int = 50, seed: int = 0):
        self.cfg, self.tcfg, self.stream = cfg, tcfg, stream
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.mesh = mesh
        self.step_fn = make_ft_train_step(cfg, tcfg, mesh)
        self.params = transformer.init(cfg, jax.random.key(seed))
        self.opt = adamw.init(self.params)
        self.err = init_error_feedback(self.params) \
            if tcfg.grad_compression else jax.tree.map(
                lambda x: jnp.zeros((1,)), {"_": jnp.zeros((1,))})
        self.step = 0
        self.monitor = StragglerMonitor()
        self.history = []
        if ckpt_dir:
            self._try_resume()

    # -- checkpoint/resume -------------------------------------------------
    def _state(self):
        return {"params": self.params, "opt": self.opt, "err": self.err}

    def _try_resume(self):
        like = self._state()
        step, tree, extra = ckpt.restore_latest(self.ckpt_dir, like)
        if step is None:
            return
        self.params, self.opt, self.err = (tree["params"], tree["opt"],
                                           tree["err"])
        self.step = step
        self.stream.cursor = Cursor.from_state(extra["cursor"])

    def save(self):
        if not self.ckpt_dir:
            return
        ckpt.save(self.ckpt_dir, self.step, self._state(),
                  extra={"cursor": self.stream.cursor.state()})

    # -- run ---------------------------------------------------------------
    def run(self, num_steps: int, lr: Optional[float] = None,
            fail_hook=None) -> Dict:
        lr = lr if lr is not None else self.tcfg.learning_rate
        it = iter(self.stream)
        losses = []
        target = self.step + num_steps
        while self.step < target:
            toks, labels = next(it)
            batch = {"tokens": jnp.asarray(toks),
                     "labels": jnp.asarray(labels)}
            t0 = time.perf_counter()

            def do_step():
                if fail_hook is not None:
                    fail_hook(self.step)
                return self.step_fn(self.params, self.opt, self.err, batch,
                                    lr)

            (self.params, self.opt, self.err, m), _ = resilient_step(
                do_step, max_retries=2, on_give_up=self.save)
            jax.block_until_ready(m["loss"])
            self.monitor.observe(time.perf_counter() - t0, self.step)
            losses.append(float(m["loss"]))
            self.step += 1
            if self.ckpt_dir and self.step % self.ckpt_every == 0:
                self.save()
        if self.ckpt_dir:
            self.save()
        self.history.extend(losses)
        return {"loss_first": losses[0], "loss_last": losses[-1],
                "losses": losses,
                "straggler_fraction": self.monitor.straggler_fraction}


def elastic_reshard(state, new_mesh):
    """Re-layout a training state onto a different mesh (elastic restart):
    compute fresh shardings for the new mesh and device_put every leaf."""
    pspec = shd.param_shardings(state["params"], new_mesh)
    return {
        "params": jax.tree.map(jax.device_put, state["params"], pspec),
        "opt": {
            "m": jax.tree.map(jax.device_put, state["opt"]["m"], pspec),
            "v": jax.tree.map(jax.device_put, state["opt"]["v"], pspec),
            "count": jax.device_put(state["opt"]["count"]),
        },
    }
