"""Runtime health: straggler detection + retry-with-backoff step execution.

At pod scale the common failure modes are (a) a slow host (data pipeline or
thermal throttling) and (b) transient device errors. The monitor keeps an
EMA of step time and flags outliers; `resilient_step` retries a step
function and escalates to a checkpoint-restore callback after repeated
failures (tested by fault injection in tests/test_fault_tolerance.py).
`HitRateMeter` accumulates the feature-cache hit/miss counters the GNN
trainer measures per batch (`repro.featcache`) into per-epoch hit rates,
plus — for dynamic CLOCK admission — the per-epoch refill churn and the
hit-rate trajectory across epochs. `ResilienceMeter` counts the recovery
actions the guarded GNN path takes (skipped non-finite steps, rollbacks,
producer watchdog restarts, corrupt-checkpoint fallbacks, cache
degradations) so chaos runs (`repro.resilience`) can assert that the
expected recovery — and ONLY the expected recovery — happened.

All three meters keep their standalone behaviour but accept an optional
`hub=` (`repro.obs.MetricsHub`): when attached, every mutation mirrors
into canonically named hub series ("cache/hits",
"resilience/rollbacks", "straggler/fraction", ...) so one registry
exports the whole stack's runtime metrics. The mirror is exact — hub
counters equal the meter's own fields at every point, pinned by
tests/test_obs.py's absorption-equivalence tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class StragglerMonitor:
    """EMA step-time tracker. `threshold` x EMA flags a straggler step."""
    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 3
    ema: float = 0.0
    count: int = 0
    events: List[dict] = field(default_factory=list)
    hub: Optional[object] = None      # repro.obs.MetricsHub mirror

    def observe(self, dt: float, step: int) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            self.ema = dt if self.ema == 0 else \
                (self.alpha * dt + (1 - self.alpha) * self.ema)
            self._mirror(dt, False)
            return False
        slow = dt > self.threshold * self.ema
        if slow:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        else:
            self.ema = self.alpha * dt + (1 - self.alpha) * self.ema
        self._mirror(dt, slow)
        return slow

    def _mirror(self, dt: float, slow: bool) -> None:
        if self.hub is None:
            return
        self.hub.counter("straggler/steps").inc()
        if slow:
            self.hub.counter("straggler/events").inc()
        self.hub.histogram("straggler/step_time_s").observe(dt)
        self.hub.gauge("straggler/fraction").set(self.straggler_fraction)

    @property
    def straggler_fraction(self) -> float:
        return len(self.events) / max(self.count - self.warmup, 1)

    def mark(self) -> tuple:
        """Window marker for per-epoch fractions (`fraction_since`)."""
        return (len(self.events), self.count)

    def fraction_since(self, mark: tuple) -> float:
        """Straggler fraction of the window opened at `mark` (observed
        steps only; the warmup steps burn off in the first window)."""
        ev0, n0 = mark
        denom = self.count - max(n0, self.warmup)
        return (len(self.events) - ev0) / max(denom, 1)


@dataclass
class HitRateMeter:
    """Feature-cache hit/miss accumulator (`repro.featcache`).

    The trainer feeds it the device counters `gather_cached` mirrors
    (one observe per batch, after the end-of-epoch sync so metrics never
    force an extra host round-trip); `mark()`/`rate_since` carve the
    running totals into per-epoch windows. With DYNAMIC admission
    (`featcache.dynamic`) it also counts refill churn (`observe_refill`,
    once per epoch boundary) and `note_epoch` records the per-epoch
    (hit rate, admitted rows) trajectory — the number the paper's
    cache-locality figures are really about: does the cache track the
    access distribution over time."""
    hits: int = 0
    misses: int = 0
    refills: int = 0                  # admitted rows, all epochs (churn)
    degraded_at: Optional[int] = None  # step the cache was dropped, if any
    trajectory: List[dict] = field(default_factory=list)
    hub: Optional[object] = None      # repro.obs.MetricsHub mirror

    def observe(self, hits, misses) -> None:
        self.hits += int(hits)
        self.misses += int(misses)
        if self.hub is not None:
            self.hub.counter("cache/hits").inc(int(hits))
            self.hub.counter("cache/misses").inc(int(misses))
            self.hub.gauge("cache/hit_rate").set(self.hit_rate)

    def observe_refill(self, admitted) -> None:
        """Count one epoch boundary's refill churn (admitted rows)."""
        self.refills += int(admitted)
        if self.hub is not None:
            self.hub.counter("cache/refills").inc(int(admitted))

    def note_degraded(self, step: int) -> None:
        """Record that the trainer dropped a corrupt cache and fell back
        to the uncached gather (graceful degradation — the trajectory
        keeps a visible marker, hit counting simply stops)."""
        self.degraded_at = step
        self.trajectory.append({"degraded": True, "step": step})
        if self.hub is not None:
            self.hub.counter("cache/degradations").inc()

    @property
    def total(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.total, 1)

    def mark(self):
        """Window marker: pass the result to `rate_since`/`note_epoch`."""
        return (self.hits, self.misses, self.refills)

    def rate_since(self, mark) -> float:
        h0, m0 = mark[0], mark[1]
        return (self.hits - h0) / max(self.total - h0 - m0, 1)

    def note_epoch(self, mark) -> dict:
        """Close the epoch window opened at `mark`: append (and return)
        `{"hit_rate", "refills"}` on the trajectory."""
        entry = {"hit_rate": self.rate_since(mark),
                 "refills": self.refills - (mark[2] if len(mark) > 2
                                            else 0)}
        self.trajectory.append(entry)
        return entry


@dataclass
class ResilienceMeter:
    """Recovery-action counters for the guarded GNN path.

    Each `note(kind, **info)` bumps the matching counter and appends the
    event (with its context) to `events`, so tests can assert both the
    count and the shape of every recovery a chaos run took."""
    skipped_steps: int = 0            # non-finite steps whose update was
    #                                   dropped by the in-jit select
    rollbacks: int = 0                # skip budget exceeded -> restore
    producer_restarts: int = 0        # AsyncBatchStream watchdog kicks
    ckpt_fallbacks: int = 0           # corrupt checkpoints skipped over
    cache_degradations: int = 0       # dynamic cache dropped to uncached
    events: List[dict] = field(default_factory=list)
    hub: Optional[object] = None      # repro.obs.MetricsHub mirror

    _KINDS = ("skipped_steps", "rollbacks", "producer_restarts",
              "ckpt_fallbacks", "cache_degradations")

    def note(self, kind: str, **info) -> None:
        if kind not in self._KINDS:
            raise ValueError(f"unknown resilience event {kind!r}; "
                             f"known: {self._KINDS}")
        setattr(self, kind, getattr(self, kind) + 1)
        self.events.append({"kind": kind, **info})
        if self.hub is not None:
            self.hub.counter(f"resilience/{kind}").inc()

    def counts(self) -> dict:
        return {k: getattr(self, k) for k in self._KINDS}


class StepFailure(RuntimeError):
    pass


def resilient_step(fn: Callable, *args, max_retries: int = 2,
                   backoff_s: float = 0.0,
                   on_give_up: Optional[Callable] = None):
    """Run `fn(*args)`; retry transient failures; escalate after retries.

    Returns (result, attempts). `on_give_up` (e.g. restore-from-checkpoint
    and rebuild step) is invoked before the final re-raise.
    """
    attempt = 0
    while True:
        try:
            return fn(*args), attempt + 1
        except Exception:  # noqa: BLE001 — deliberately broad: device loss
            attempt += 1
            if attempt > max_retries:
                if on_give_up is not None:
                    on_give_up()
                raise
            if backoff_s:
                time.sleep(backoff_s * attempt)
