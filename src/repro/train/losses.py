"""Losses. The LM loss is a sequence-chunked, rematerialized softmax
cross-entropy: the (B, S, V) logits tensor never materializes (V up to 262k
makes it the dominant activation otherwise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd


def _ce_chunk(hidden, head, labels, mask):
    """hidden (B,C,d) fp32-castable; head (d,V); labels (B,C)."""
    hidden = shd.act_ce_hidden(hidden)
    logits = shd.act_logits(hidden @ head).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - picked) * mask
    return nll.sum(), mask.sum()


def chunked_cross_entropy(hidden, head, labels, mask=None, chunk=512):
    """Mean next-token NLL, scanning the sequence in `chunk` slices with
    rematerialization (logits recomputed in backward)."""
    B, S, d = hidden.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    if S % chunk != 0:
        chunk = S
    n = S // chunk

    hc = jnp.moveaxis(hidden.reshape(B, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        tot, cnt = carry
        h, l, m = inp
        s, c = _ce_chunk(h, head, l, m)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def gnn_softmax_ce(logits, labels, mask):
    """Node-classification CE over root nodes. logits (N, C)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    nll = (lse - picked) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def accuracy(logits, labels, mask):
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels).astype(jnp.float32) * mask
    return correct.sum() / jnp.maximum(mask.sum(), 1.0)
