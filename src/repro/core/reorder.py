"""Community-based graph reordering (RABBIT-style, paper §3 Figure 1).

Nodes of the same community get consecutive ids; communities are laid out by
size (hot/large first — the degree-ordered flavor of rabbit ordering).
The same module exposes `prepare`, the one-call preprocessing pipeline:
detect (or adopt oracle) communities -> reorder -> intra-first row layout.
"""
from __future__ import annotations

import numpy as np

from repro.core.community import louvain
from repro.graphs.csr import Graph, intra_first_layout, reorder


def community_permutation(communities: np.ndarray,
                          degrees: np.ndarray = None) -> np.ndarray:
    """perm[i] = old id of the node that becomes new id i."""
    if degrees is None:
        key = communities.astype(np.int64)
        return np.argsort(key, kind="stable")
    # order communities by total degree (hot communities first), nodes by
    # degree inside each community
    n_comm = communities.max() + 1
    comm_deg = np.zeros(n_comm, np.int64)
    np.add.at(comm_deg, communities, degrees)
    comm_rank = np.empty(n_comm, np.int64)
    comm_rank[np.argsort(-comm_deg, kind="stable")] = np.arange(n_comm)
    return np.lexsort((-degrees, comm_rank[communities]))


def prepare(graph: Graph, *, oracle: bool = True, levels: int = 2,
            seed: int = 0) -> Graph:
    """Full preprocessing: communities -> reorder -> intra-first layout."""
    if graph.communities is None or not oracle:
        comm = louvain(graph.indptr, graph.indices, levels=levels, seed=seed)
        graph = type(graph)(**{**graph.__dict__, "communities": comm})
    perm = community_permutation(graph.communities, graph.degrees())
    g2 = reorder(graph, perm)
    g2 = intra_first_layout(g2)
    return g2


def community_bounds(communities: np.ndarray) -> np.ndarray:
    """For a community-sorted graph: start offsets of each community
    (len n_comm+1)."""
    n_comm = communities.max() + 1
    bounds = np.zeros(n_comm + 1, np.int64)
    np.add.at(bounds, communities + 1, 1)
    np.cumsum(bounds, out=bounds)
    return bounds
