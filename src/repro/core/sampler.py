"""DEPRECATED — neighbor sampling moved to `repro.sampling`.

This module used to hardcode the paper's biased two-phase draw (§4.2) plus
a `mode="all"` string knob for full-neighborhood enumeration. Both are now
registered samplers in the pluggable `repro.sampling` subsystem:

    sample_neighbors(key, g, nodes, fanout, p)   -> BiasedTwoPhaseSampler(p)
    sample_neighbors(..., mode="all")            -> FullNeighborhoodSampler()

The shim below delegates bit-exactly (same key splits, same draws) and
will be removed once external callers migrate. One contract change: `p`
is consumed as a static Python float now (samplers are hashable static
jit arguments), so calling this shim with a traced `p` under an outer
`jax.jit` is no longer supported — pass a concrete float, or construct
the sampler yourself.
"""
from __future__ import annotations

import warnings

from repro.sampling import BiasedTwoPhaseSampler, FullNeighborhoodSampler


def sample_neighbors(key, g, nodes, fanout: int, p, mode: str = "sample"):
    """Deprecated: use `repro.sampling.make_sampler(...)` instead.

    nodes: (M,) int32, sentinel `num_nodes` for padding. Returns
    (srcs (M, fanout) int32 — sentinel-propagating, self-loop for isolated
    nodes; mask (M, fanout) bool).
    """
    warnings.warn(
        "repro.core.sampler.sample_neighbors is deprecated; use the "
        "repro.sampling registry (BiasedTwoPhaseSampler / "
        "FullNeighborhoodSampler)", DeprecationWarning, stacklevel=2)
    if mode == "all":
        sampler = FullNeighborhoodSampler()
    else:
        sampler = BiasedTwoPhaseSampler(p=float(p))
    return sampler.sample(key, g, nodes, int(fanout))
