"""Biased neighborhood sampling (paper §4.2, Figure 4).

Intra-community edges are drawn with unnormalized weight `p`, inter-community
with `1-p`. Thanks to the intra-first CSR row layout (`n_intra[u]` split
point), a draw is two-phase: pick the class with prob
p*n_intra / (p*n_intra + (1-p)*n_inter), then uniform within the class —
O(1) per sample, no per-edge weight array (the DGL implementation the paper
uses carries an |E|-sized probability vector instead).

`mode='all'` enumerates neighbors deterministically (fanout >= max degree
gives exact full-neighborhood aggregation — used by equivalence tests).
Sampling is with replacement within the class (DESIGN.md §7).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs.csr import DeviceGraph


@partial(jax.jit, static_argnames=("fanout", "mode"))
def sample_neighbors(key, g: DeviceGraph, nodes, fanout: int, p,
                     mode: str = "sample"):
    """nodes: (M,) int32, sentinel `num_nodes` for padding.

    Returns (srcs (M, fanout) int32 — sentinel-propagating, self-loop for
    isolated nodes; mask (M, fanout) bool).
    """
    N = g.num_nodes
    M = nodes.shape[0]
    valid = nodes < N
    safe = jnp.where(valid, nodes, 0)
    start = g.indptr[safe]
    deg = g.degrees[safe]
    ni = g.n_intra[safe]
    no = deg - ni

    if mode == "all":
        j = jnp.broadcast_to(jnp.arange(fanout), (M, fanout))
        mask = (j < deg[:, None]) & valid[:, None]
        offset = jnp.minimum(j, jnp.maximum(deg - 1, 0)[:, None])
        src = g.indices[start[:, None] + offset]
        src = jnp.where(mask, src, jnp.where(valid[:, None], safe[:, None], N))
        return src.astype(jnp.int32), mask

    k1, k2, k3 = jax.random.split(key, 3)
    w_i = p * ni.astype(jnp.float32)
    w_o = (1.0 - p) * no.astype(jnp.float32)
    p_intra = jnp.where(w_i + w_o > 0, w_i / jnp.maximum(w_i + w_o, 1e-9), 0.0)
    p_intra = jnp.where(no == 0, 1.0, jnp.where(ni == 0, 0.0, p_intra))

    u_class = jax.random.uniform(k1, (M, fanout))
    intra = u_class < p_intra[:, None]
    u_off = jax.random.uniform(k2, (M, fanout))
    off_i = jnp.floor(u_off * ni[:, None]).astype(jnp.int32)
    off_o = ni[:, None] + jnp.floor(u_off * no[:, None]).astype(jnp.int32)
    offset = jnp.where(intra, off_i, off_o)
    offset = jnp.clip(offset, 0, jnp.maximum(deg - 1, 0)[:, None])
    src = g.indices[start[:, None] + offset]
    # isolated nodes aggregate themselves; padded nodes propagate sentinel
    src = jnp.where(deg[:, None] > 0, src, safe[:, None])
    src = jnp.where(valid[:, None], src, N)
    mask = valid[:, None] & jnp.broadcast_to(deg[:, None] > 0, (M, fanout))
    return src.astype(jnp.int32), mask
