"""Static-shape mini-batch construction (the TPU-native core of COMM-RAND).

A batch is a tower of node levels F_0 (roots) ⊂ F_1 ⊂ ... ⊂ F_L (input
level), built by biased neighbor sampling + *static-size dedup*
(`jnp.unique(..., size=cap)`). The caps are CALIBRATED PER POLICY
(`calibrate_caps`): community-biased policies dedup far more aggressively, so
their compiled batches carry smaller gather buffers — the paper's working-set
reduction, expressed at compile time (DESIGN.md §2).

Blocks are stored input-side first: blocks[0] maps F_L -> F_{L-1}. Every dst
has exactly `fanout` sampled source slots + one self slot, so aggregation is
a masked mean over a dense (n_dst, fanout, dim) gather — no segment ops.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.batching.policy import CommRandPolicy
from repro.core import partition
from repro.core.sampler import sample_neighbors
from repro.graphs.csr import DeviceGraph, Graph


@jax.tree_util.register_dataclass
@dataclass
class Block:
    src_pos: jnp.ndarray     # (n_dst, fanout) int32 positions into src level
    self_pos: jnp.ndarray    # (n_dst,) int32 position of dst in src level
    edge_mask: jnp.ndarray   # (n_dst, fanout) bool
    dst_mask: jnp.ndarray    # (n_dst,) bool


@jax.tree_util.register_dataclass
@dataclass
class MiniBatch:
    levels: List[jnp.ndarray]  # per-level sorted unique node ids, 0=roots
    node_mask: jnp.ndarray   # (cap_L,) bool — input level validity
    blocks: List[Block]      # input-side first
    labels: jnp.ndarray      # (B,) int32 (aligned with levels[0])
    label_mask: jnp.ndarray  # (B,) bool

    @property
    def node_ids(self):
        """Input-level unique node ids (feature-gather index)."""
        return self.levels[-1]

    @property
    def roots(self):
        return self.levels[0]

    @property
    def num_unique(self):
        return self.node_mask.sum()


def _positions(level: jnp.ndarray, ids: jnp.ndarray):
    """Map node ids -> positions in the sorted unique `level` array."""
    pos = jnp.searchsorted(level, ids).astype(jnp.int32)
    pos = jnp.minimum(pos, level.shape[0] - 1)
    ok = level[pos] == ids
    return pos, ok


@functools.partial(jax.jit,
                   static_argnames=("fanouts", "caps", "mode"))
def build_batch(key, g: DeviceGraph, roots, labels_all, fanouts: Tuple[int],
                caps: Tuple[int], p, mode: str = "sample") -> MiniBatch:
    """roots: (B,) int32 with -1 padding. caps: per-level unique caps,
    len == len(fanouts), cap for levels 1..L (level 0 cap is B)."""
    N = g.num_nodes
    B = roots.shape[0]
    root_mask = roots >= 0
    level = jnp.where(root_mask, roots, N).astype(jnp.int32)
    # roots must be sorted for searchsorted-based mapping; keep label order
    level = jnp.sort(level)
    labels = jnp.where(root_mask, labels_all[jnp.where(
        root_mask, roots, 0)], 0)

    levels = [level]
    blocks = []
    keys = jax.random.split(key, len(fanouts))
    for h, (r, cap) in enumerate(zip(fanouts, caps)):
        prev = levels[-1]
        srcs, smask = sample_neighbors(keys[h], g, prev, r, p, mode=mode)
        all_ids = jnp.concatenate([prev, srcs.reshape(-1)])
        nxt = jnp.unique(all_ids, size=cap, fill_value=N).astype(jnp.int32)
        self_pos, self_ok = _positions(nxt, prev)
        src_pos, src_ok = _positions(nxt, srcs.reshape(-1))
        blocks.append(Block(
            src_pos=src_pos.reshape(prev.shape[0], r),
            self_pos=self_pos,
            edge_mask=(smask & src_ok.reshape(prev.shape[0], r)
                       & (srcs < N)),
            dst_mask=(prev < N) & self_ok,
        ))
        levels.append(nxt)

    top = levels[-1]
    # labels aligned to the SORTED root level: re-gather via positions
    root_pos, _ = _positions(levels[0], jnp.where(root_mask, roots, N))
    lab_sorted = jnp.zeros((B,), labels_all.dtype).at[root_pos].set(
        jnp.where(root_mask, labels, 0), mode="drop")
    lmask = jnp.zeros((B,), bool).at[root_pos].set(root_mask, mode="drop")
    return MiniBatch(
        levels=levels,
        node_mask=top < N,
        blocks=blocks[::-1],
        labels=lab_sorted,
        label_mask=lmask & (levels[0] < N),
    )


# ---------------------------------------------------------------------------
# numpy reference builder (exact dedup; calibration + test oracle)
# ---------------------------------------------------------------------------
def build_batch_np(rng: np.random.Generator, graph: Graph, roots, fanouts,
                   p: float):
    """Returns per-level unique-node counts + the input-level footprint."""
    comm = graph.communities
    level = np.unique(roots[roots >= 0])
    sizes = [len(level)]
    for r in fanouts:
        srcs = []
        for u in level:
            s, e = graph.indptr[u], graph.indptr[u + 1]
            nbrs = graph.indices[s:e]
            if len(nbrs) == 0:
                srcs.append(np.array([u] * r))
                continue
            intra = comm[nbrs] == comm[u]
            ni, no = int(intra.sum()), int((~intra).sum())
            w_i, w_o = p * ni, (1 - p) * no
            pi = 1.0 if no == 0 else (0.0 if ni == 0 else w_i / (w_i + w_o))
            cls = rng.random(r) < pi
            nbr_i = nbrs[intra] if ni else nbrs
            nbr_o = nbrs[~intra] if no else nbrs
            pick = np.where(cls, nbr_i[rng.integers(0, max(ni, 1), r)],
                            nbr_o[rng.integers(0, max(no, 1), r)])
            srcs.append(pick)
        level = np.unique(np.concatenate([level] + srcs))
        sizes.append(len(level))
    return sizes, level


def calibrate_caps(graph: Graph, policy: CommRandPolicy, batch_size: int,
                   fanouts, n_probe: int = 6, margin: float = 1.15,
                   seed: int = 0, align: int = 128) -> Tuple[int, ...]:
    """Policy-derived static caps: max unique nodes per level over probe
    batches x margin, rounded up to `align` (TPU-friendly shapes).

    Probe batch indices are drawn uniformly across the epoch: under
    comm_rand the LEADING batches of an epoch order are community-pure and
    under-estimate the footprint of the late, mixed batches."""
    rng = np.random.default_rng(seed)
    maxes = np.zeros(len(fanouts), np.int64)
    probes = 0
    while probes < n_probe:
        batches = partition.batches_for_epoch(
            graph.train_ids, graph.communities, policy, batch_size, rng)
        take = min(max(1, n_probe - probes), len(batches))
        idx = np.sort(rng.choice(len(batches), size=take, replace=False))
        for b in batches[idx]:
            sizes, _ = build_batch_np(rng, graph, b, fanouts, policy.p)
            maxes = np.maximum(maxes, sizes[1:])
            probes += 1
            if probes >= n_probe:
                break
    caps = []
    lo = batch_size
    for m in maxes:
        c = int(np.ceil(m * margin / align) * align)
        c = max(c, lo + align)       # level must fit its predecessor
        caps.append(c)
        lo = c
    return tuple(caps)


def feature_bytes(batch_or_cap, feat_dim: int, itemsize: int = 4) -> int:
    """Paper Fig 6 metric: input feature bytes gathered per batch."""
    if isinstance(batch_or_cap, (int, np.integer)):
        return int(batch_or_cap) * feat_dim * itemsize
    return int(batch_or_cap.num_unique) * feat_dim * itemsize
