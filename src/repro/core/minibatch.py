"""Static-shape mini-batch construction (the TPU-native core of COMM-RAND).

A batch is a tower of node levels F_0 (roots) ⊂ F_1 ⊂ ... ⊂ F_L (input
level), built by pluggable neighbor sampling (`repro.sampling`) + *static-
size dedup* (`jnp.unique(..., size=cap)`). The caps are CALIBRATED PER
(POLICY, SAMPLER) (`calibrate_caps`): community-biased policies — and
LABOR's shared-randomness sampler — dedup far more aggressively, so their
compiled batches carry smaller gather buffers: the paper's working-set
reduction, expressed at compile time (DESIGN.md §2).

The sampler rides through jit as a STATIC argument (samplers are frozen
dataclasses), so each sampler gets its own compiled builder. Samplers with
`shared_randomness` (LABOR) receive the EPOCH-level key — identical across
hops and batches — instead of the per-(batch, hop) key, which is what
makes overlapping neighborhoods pick identical neighbors.

Blocks are stored input-side first: blocks[0] maps F_L -> F_{L-1}. Every dst
has exactly `fanout` sampled source slots + one self slot, so aggregation is
a masked mean over a dense (n_dst, fanout, dim) gather — no segment ops.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sampling
from repro.core import partition
from repro.graphs.csr import DeviceGraph, Graph


@jax.tree_util.register_dataclass
@dataclass
class Block:
    src_pos: jnp.ndarray     # (n_dst, fanout) int32 positions into src level
    self_pos: jnp.ndarray    # (n_dst,) int32 position of dst in src level
    edge_mask: jnp.ndarray   # (n_dst, fanout) bool
    dst_mask: jnp.ndarray    # (n_dst,) bool


@jax.tree_util.register_dataclass
@dataclass
class MiniBatch:
    levels: List[jnp.ndarray]  # per-level sorted unique node ids, 0=roots
    node_mask: jnp.ndarray   # (cap_L,) bool — input level validity
    blocks: List[Block]      # input-side first
    labels: jnp.ndarray      # (B,) int32 (aligned with levels[0])
    label_mask: jnp.ndarray  # (B,) bool

    @property
    def node_ids(self):
        """Input-level unique node ids (feature-gather index)."""
        return self.levels[-1]

    @property
    def roots(self):
        return self.levels[0]

    @property
    def num_unique(self):
        return self.node_mask.sum()


def _positions(level: jnp.ndarray, ids: jnp.ndarray):
    """Map node ids -> positions in the sorted unique `level` array."""
    pos = jnp.searchsorted(level, ids).astype(jnp.int32)
    pos = jnp.minimum(pos, level.shape[0] - 1)
    ok = level[pos] == ids
    return pos, ok


def sampler_epoch_ctx(sampler, epoch_key, g: DeviceGraph):
    """Per-epoch device state a shared-randomness sampler can precompute
    once (LABOR's node ranks). None for samplers without one. The batch
    builder computes it once per build; `repro.pipeline.DeviceBatchBuilder`
    hoists it further, to once per EPOCH."""
    fn = getattr(sampler, "epoch_ctx", None)
    if sampler.shared_randomness and callable(fn):
        return fn(epoch_key, g)
    return None


def _build_batch_impl(key, epoch_key, g: DeviceGraph, roots, labels_all,
                      fanouts: Tuple[int], caps: Tuple[int],
                      sampler, shared_ctx=None) -> MiniBatch:
    """The (jit-traceable) build body, shared by the host-driven
    `_build_batch` below and the fused on-device builder in
    `repro.pipeline.builder` — ONE implementation so the async pipeline's
    batch sequence is bit-exact against the synchronous stream."""
    N = g.num_nodes
    B = roots.shape[0]
    root_mask = roots >= 0
    level = jnp.where(root_mask, roots, N).astype(jnp.int32)
    # roots must be sorted for searchsorted-based mapping; keep label order
    level = jnp.sort(level)
    labels = jnp.where(root_mask, labels_all[jnp.where(
        root_mask, roots, 0)], 0)

    # shared per-epoch sampler state (LABOR ranks): computed once per
    # build instead of once per hop — a pure function of the epoch key,
    # so hoisting cannot change any pick
    if shared_ctx is None:
        shared_ctx = sampler_epoch_ctx(sampler, epoch_key, g)

    levels = [level]
    blocks = []
    keys = jax.random.split(key, len(fanouts))
    for h, (r, cap) in enumerate(zip(fanouts, caps)):
        prev = levels[-1]
        # shared-randomness samplers (LABOR) draw from the epoch key so the
        # same source node picks the same neighbors at every hop and batch
        k_h = epoch_key if sampler.shared_randomness else keys[h]
        if shared_ctx is not None:
            srcs, smask = sampler.sample(k_h, g, prev, r, ranks=shared_ctx)
        else:
            srcs, smask = sampler.sample(k_h, g, prev, r)
        all_ids = jnp.concatenate([prev, srcs.reshape(-1)])
        nxt = jnp.unique(all_ids, size=cap, fill_value=N).astype(jnp.int32)
        self_pos, self_ok = _positions(nxt, prev)
        src_pos, src_ok = _positions(nxt, srcs.reshape(-1))
        blocks.append(Block(
            src_pos=src_pos.reshape(prev.shape[0], r),
            self_pos=self_pos,
            edge_mask=(smask & src_ok.reshape(prev.shape[0], r)
                       & (srcs < N)),
            dst_mask=(prev < N) & self_ok,
        ))
        levels.append(nxt)

    top = levels[-1]
    # labels aligned to the SORTED root level: re-gather via positions
    root_pos, _ = _positions(levels[0], jnp.where(root_mask, roots, N))
    lab_sorted = jnp.zeros((B,), labels_all.dtype).at[root_pos].set(
        jnp.where(root_mask, labels, 0), mode="drop")
    lmask = jnp.zeros((B,), bool).at[root_pos].set(root_mask, mode="drop")
    return MiniBatch(
        levels=levels,
        node_mask=top < N,
        blocks=blocks[::-1],
        labels=lab_sorted,
        label_mask=lmask & (levels[0] < N),
    )


@functools.partial(jax.jit,
                   static_argnames=("fanouts", "caps", "sampler"))
def _build_batch(key, epoch_key, g: DeviceGraph, roots, labels_all,
                 fanouts: Tuple[int], caps: Tuple[int],
                 sampler, shared_ctx=None) -> MiniBatch:
    return _build_batch_impl(key, epoch_key, g, roots, labels_all,
                             fanouts, caps, sampler, shared_ctx)


def build_batch(key, g: DeviceGraph, roots, labels_all, fanouts: Tuple[int],
                caps: Tuple[int], sampler=0.5, mode: str = "sample", *,
                epoch_key=None) -> MiniBatch:
    """roots: (B,) int32 with -1 padding. caps: per-level unique caps,
    len == len(fanouts), cap for levels 1..L (level 0 cap is B).

    `sampler` is a `repro.sampling` sampler (or registry name/spec); a
    bare float is the legacy signature and selects the biased two-phase
    draw at that `p` (`mode="all"` likewise maps to the full-neighborhood
    sampler) — see `sampling.resolve` for the one precedence rule.
    `epoch_key` feeds shared-randomness samplers; it defaults to `key`,
    which keeps direct calls deterministic but shares picks only within
    this one batch — streams pass the real epoch key.
    """
    s = sampling.resolve(sampler, mode)
    if epoch_key is None:
        epoch_key = key
    return _build_batch(key, epoch_key, g, roots, labels_all,
                        tuple(fanouts), tuple(caps), s)


# ---------------------------------------------------------------------------
# numpy reference builder (exact dedup; calibration + test oracle)
# ---------------------------------------------------------------------------
def build_batch_np(rng: np.random.Generator, graph: Graph, roots, fanouts,
                   sampler=0.5, ctx: dict = None):
    """Returns per-level unique-node counts + the input-level footprint.
    `sampler` follows `build_batch`'s convention (float p == biased);
    `ctx` carries per-epoch shared sampler state (LABOR's ranks) across
    batches of one epoch."""
    s = sampling.resolve(sampler)
    ctx = {} if ctx is None else ctx
    level = np.unique(roots[roots >= 0])
    sizes = [len(level)]
    for r in fanouts:
        srcs = s.sample_level_np(rng, graph, level, r, ctx)
        level = np.unique(np.concatenate([level] + list(srcs)))
        sizes.append(len(level))
    return sizes, level


def calibrate_caps(graph: Graph, policy, batch_size: int,
                   fanouts, n_probe: int = 6, margin: float = 1.15,
                   seed: int = 0, align: int = 128) -> Tuple[int, ...]:
    """Policy-derived static caps: max unique nodes per level over probe
    batches x margin, rounded up to `align` (TPU-friendly shapes). The
    probe samples through the policy's BOUND SAMPLER (`sampler_spec()`),
    so e.g. LABOR's collapsed footprint yields smaller caps.

    Probe batch indices are drawn uniformly across the epoch: under
    comm_rand the LEADING batches of an epoch order are community-pure and
    under-estimate the footprint of the late, mixed batches."""
    # salt 0 = legacy stream slot (trailing-zero tuples are
    # stream-identical by the SeedSequence spec): calibrated caps
    # stay bit-stable against pre-conversion runs
    rng = np.random.default_rng((seed, 0))
    s = sampling.for_policy(policy)
    maxes = np.zeros(len(fanouts), np.int64)
    probes = 0
    while probes < n_probe:
        ctx = {}                        # fresh shared state per probe epoch
        batches = partition.batches_for_epoch(
            graph.train_ids, graph.communities, policy, batch_size, rng)
        take = min(max(1, n_probe - probes), len(batches))
        idx = np.sort(rng.choice(len(batches), size=take, replace=False))
        for b in batches[idx]:
            sizes, _ = build_batch_np(rng, graph, b, fanouts, s, ctx=ctx)
            maxes = np.maximum(maxes, sizes[1:])
            probes += 1
            if probes >= n_probe:
                break
    caps = []
    lo = batch_size
    for m in maxes:
        c = int(np.ceil(m * margin / align) * align)
        c = max(c, lo + align)       # level must fit its predecessor
        caps.append(c)
        lo = c
    return tuple(caps)


def feature_bytes(batch_or_cap, feat_dim: int, itemsize: int = 4) -> int:
    """Paper Fig 6 metric: input feature bytes gathered per batch."""
    if isinstance(batch_or_cap, (int, np.integer)):
        return int(batch_or_cap) * feat_dim * itemsize
    return int(batch_or_cap.num_unique) * feat_dim * itemsize
