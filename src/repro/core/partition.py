"""Biased root-node partitioning (paper §4.1, Figure 3, Table 1).

Policies over the training set, per epoch:
  RAND-ROOTS        uniform random shuffle (baseline *)
  NORAND-ROOTS      no shuffle — static community order
  COMM-RAND-MIX-k%  communities shuffled as blocks; consecutive groups of
                    max(1, round(k * n_comm)) shuffled communities merge into
                    super-blocks; contents shuffled WITHIN each super-block.

k=0 is the paper's COMM-RAND-MIX-0% (block shuffle + intra-community
shuffle). Larger k mixes more communities -> more randomness, less bias.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.configs.base import CommRandPolicy


def group_train_by_community(train_ids: np.ndarray,
                             communities: np.ndarray) -> List[np.ndarray]:
    """Training-set node ids grouped per community (ascending comm id)."""
    comm = communities[train_ids]
    order = np.argsort(comm, kind="stable")
    sorted_ids = train_ids[order]
    sorted_comm = comm[order]
    cuts = np.flatnonzero(np.diff(sorted_comm)) + 1
    return np.split(sorted_ids, cuts)


def epoch_order(train_ids: np.ndarray, communities: np.ndarray,
                policy: CommRandPolicy, rng: np.random.Generator
                ) -> np.ndarray:
    """The (possibly constrained-random) permutation of the training set for
    one epoch."""
    if policy.root_mode == "rand":
        return rng.permutation(train_ids)
    groups = group_train_by_community(train_ids, communities)
    if policy.root_mode == "norand":
        return np.concatenate(groups)
    if policy.root_mode != "comm_rand":
        raise ValueError(policy.root_mode)
    n_comm = len(groups)
    # (1) shuffle communities as whole blocks
    block_order = rng.permutation(n_comm)
    # (2) merge consecutive shuffled blocks into super-blocks of m
    m = max(1, int(round(policy.mix * n_comm)))
    out = []
    for i in range(0, n_comm, m):
        sb = np.concatenate([groups[j] for j in block_order[i:i + m]])
        rng.shuffle(sb)              # (3) shuffle within the super-block
        out.append(sb)
    return np.concatenate(out)


def make_batches(order: np.ndarray, batch_size: int,
                 drop_last: bool = False) -> np.ndarray:
    """Split an epoch order into (n_batches, batch_size); last batch padded
    with -1 unless drop_last."""
    n = len(order)
    if drop_last:
        n_batches = n // batch_size
        return order[:n_batches * batch_size].reshape(n_batches, batch_size)
    n_batches = (n + batch_size - 1) // batch_size
    out = np.full((n_batches, batch_size), -1, order.dtype)
    out.flat[:n] = order
    return out


def batches_for_epoch(train_ids, communities, policy, batch_size, rng,
                      drop_last: bool = False) -> np.ndarray:
    return make_batches(
        epoch_order(train_ids, communities, policy, rng), batch_size,
        drop_last)


# ---------------------------------------------------------------------------
# diagnostics used by the paper's figures
# ---------------------------------------------------------------------------
def labels_per_batch(batches: np.ndarray, labels: np.ndarray) -> float:
    """Fig 7 metric: mean #distinct labels among batch root nodes."""
    counts = []
    for b in batches:
        ids = b[b >= 0]
        counts.append(len(np.unique(labels[ids])))
    return float(np.mean(counts))


def communities_per_batch(batches: np.ndarray, communities) -> float:
    counts = []
    for b in batches:
        ids = b[b >= 0]
        counts.append(len(np.unique(communities[ids])))
    return float(np.mean(counts))
