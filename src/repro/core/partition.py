"""Biased root-node partitioning (paper §4.1, Figure 3, Table 1).

Policies over the training set, per epoch:
  RAND-ROOTS        uniform random shuffle (baseline *)
  NORAND-ROOTS      no shuffle — static community order
  COMM-RAND-MIX-k%  communities shuffled as blocks; consecutive groups of
                    max(1, round(k * n_comm)) shuffled communities merge into
                    super-blocks; contents shuffled WITHIN each super-block.

k=0 is the paper's COMM-RAND-MIX-0% (block shuffle + intra-community
shuffle). Larger k mixes more communities -> more randomness, less bias.

DEPRECATED entry point: the ordering logic lives in `repro.batching`
(`policy.py` dispatches per policy, `order.py` owns the block-shuffle
operator). These functions are kept as thin delegating shims; the
figure-diagnostic helpers (`labels_per_batch`, `communities_per_batch`)
still live here.
"""
from __future__ import annotations

import numpy as np

from repro.batching import order as _order
from repro.batching.policy import as_policy
# re-exported shims — the canonical implementations moved to repro.batching
from repro.batching.order import make_batches  # noqa: F401


def group_train_by_community(train_ids: np.ndarray,
                             communities: np.ndarray):
    """Training-set node ids grouped per community (ascending comm id)."""
    return _order.community_groups(train_ids, communities)


def epoch_order(train_ids: np.ndarray, communities: np.ndarray,
                policy, rng: np.random.Generator) -> np.ndarray:
    """The (possibly constrained-random) permutation of the training set for
    one epoch. `policy` may be a policy object or a registered name."""
    return as_policy(policy).epoch_order(train_ids, communities, rng)


def batches_for_epoch(train_ids, communities, policy, batch_size, rng,
                      drop_last: bool = False) -> np.ndarray:
    return make_batches(
        epoch_order(train_ids, communities, policy, rng), batch_size,
        drop_last)


# ---------------------------------------------------------------------------
# diagnostics used by the paper's figures
# ---------------------------------------------------------------------------
def labels_per_batch(batches: np.ndarray, labels: np.ndarray) -> float:
    """Fig 7 metric: mean #distinct labels among batch root nodes."""
    counts = []
    for b in batches:
        ids = b[b >= 0]
        counts.append(len(np.unique(labels[ids])))
    return float(np.mean(counts))


def communities_per_batch(batches: np.ndarray, communities) -> float:
    counts = []
    for b in batches:
        ids = b[b >= 0]
        counts.append(len(np.unique(communities[ids])))
    return float(np.mean(counts))
