"""LRU software-cache simulator (paper §6.5.1/§6.5.2 analogue).

The paper measures a DGL GPU-resident feature cache (UVA path) and MIG-cut
L2 capacities; neither exists on TPU, so we *model* the cache: replay the
exact per-batch feature-access streams produced by each policy through an
LRU of a given capacity and report miss rates. The paper's numbers to match
qualitatively: baseline 35.46% vs COMM-RAND-MIX-{50..0}% = 20.99/11.39/
6.22/6.21% (Fig 9), and growing speedups as capacity shrinks (Fig 10).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Iterable, List

import numpy as np


def lru_miss_rate(batches: Iterable[np.ndarray], capacity: int) -> float:
    """batches: per-batch arrays of accessed node ids (already deduped)."""
    cache: OrderedDict = OrderedDict()
    hits = 0
    total = 0
    for ids in batches:
        for u in np.asarray(ids):
            u = int(u)
            total += 1
            if u in cache:
                cache.move_to_end(u)
                hits += 1
            else:
                cache[u] = True
                if len(cache) > capacity:
                    cache.popitem(last=False)
    return 1.0 - hits / max(total, 1)


def policy_access_stream(graph, policy, batch_size, fanouts, n_batches=16,
                         seed=0) -> List[np.ndarray]:
    """Unique input-node ids per batch under `policy` (numpy builder),
    sampled through the policy's bound sampler. The shared `ctx` spans the
    whole stream, so LABOR's per-epoch ranks persist across batches — the
    cross-batch repetition is exactly what an LRU cache rewards."""
    from repro import sampling
    from repro.core import partition
    from repro.core.minibatch import build_batch_np
    rng = np.random.default_rng(seed)
    batches = partition.batches_for_epoch(
        graph.train_ids, graph.communities, policy, batch_size, rng)
    sampler = sampling.for_policy(policy)
    ctx = {}
    out = []
    for b in batches[:n_batches]:
        _, level = build_batch_np(rng, graph, b, fanouts, sampler, ctx=ctx)
        out.append(level)
    return out
