"""DEPRECATED — cache simulation moved to `repro.featcache.sim`.

The LRU replay this module used to implement is now part of the
device-resident feature-cache subsystem (`repro.featcache`): the simulator
gained a vectorized stack-distance implementation plus a CLOCK variant,
and the static cache it used to stand in for actually exists
(`featcache.CachePlan` + the `gather_cached` kernel). The shims below
delegate (the vectorized `lru_miss_rate` is exactly loop-equivalent) and
will be removed once external callers migrate.
"""
from __future__ import annotations

import warnings

from repro.featcache import sim as _sim


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.core.cachesim.{name} is deprecated; use "
        f"repro.featcache.sim.{name}", DeprecationWarning, stacklevel=3)


def lru_miss_rate(batches, capacity):
    """Deprecated: use `repro.featcache.sim.lru_miss_rate`."""
    _warn("lru_miss_rate")
    return _sim.lru_miss_rate(batches, capacity)


def policy_access_stream(graph, policy, batch_size, fanouts, n_batches=16,
                         seed=0):
    """Deprecated: use `repro.featcache.sim.policy_access_stream`."""
    _warn("policy_access_stream")
    return _sim.policy_access_stream(graph, policy, batch_size, fanouts,
                                     n_batches=n_batches, seed=seed)
