"""Community detection: Louvain-style modularity maximization (2 levels).

The paper uses RABBIT (parallel hierarchical modularity clustering); COMM-RAND
only needs *a* community assignment (§4, footnote 3), so a single-process
Louvain is sufficient here. Synthetic datasets also carry ground-truth
("oracle") communities to decouple detector quality from policy behavior.
"""
from __future__ import annotations

import numpy as np


def _local_moving(indptr, indices, comm, max_sweeps=5, rng=None):
    """One Louvain level: greedy modularity local moving. Returns (comm,
    improved)."""
    N = len(indptr) - 1
    deg = np.diff(indptr).astype(np.float64)
    two_m = deg.sum()
    if two_m == 0:
        return comm, False
    sigma_tot = np.zeros(comm.max() + 1 if len(comm) else 1, np.float64)
    np.add.at(sigma_tot, comm, deg)
    improved_any = False
    order = np.arange(N)
    # salt 0 is the reserved legacy slot: a trailing-zero SeedSequence
    # tuple spawns the SAME stream as the old bare-int seed, so pinned
    # partitions stay bit-stable (new call sites take nonzero salts)
    rng = rng or np.random.default_rng((0, 0))
    for _ in range(max_sweeps):
        rng.shuffle(order)
        moved = 0
        for u in order:
            s, e = indptr[u], indptr[u + 1]
            if s == e:
                continue
            nbrs = indices[s:e]
            nbrs = nbrs[nbrs != u]       # self-loops move with u; exclude
            if len(nbrs) == 0:
                continue
            cu = comm[u]
            # edge weight from u to each neighboring community
            ncomms, k_in = np.unique(comm[nbrs], return_counts=True)
            sigma_tot[cu] -= deg[u]
            # modularity gain of moving u into c: k_in(c) - deg_u*S_tot(c)/2m
            gain = k_in - deg[u] * sigma_tot[ncomms] / two_m
            best = ncomms[np.argmax(gain)]
            in_cu = ncomms == cu
            k_in_cu = float(k_in[in_cu][0]) if in_cu.any() else 0.0
            cur_gain = k_in_cu - deg[u] * sigma_tot[cu] / two_m
            if gain.max() > cur_gain + 1e-12 and best != cu:
                comm[u] = best
                moved += 1
            sigma_tot[comm[u]] += deg[u]
        improved_any |= moved > 0
        if moved == 0:
            break
    return comm, improved_any


def _compress(comm):
    uniq, inv = np.unique(comm, return_inverse=True)
    return inv.astype(np.int32), len(uniq)


def _aggregate(indptr, indices, comm, n_comm):
    """Community meta-graph with multiplicity preserved, INCLUDING
    intra-community self-loops (required for correct degrees/modularity at
    the next level)."""
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    cs, cd = comm[src], comm[indices]
    order = np.argsort(cs, kind="stable")
    cs, cd = cs[order], cd[order]
    new_indptr = np.zeros(n_comm + 1, np.int64)
    np.add.at(new_indptr, cs + 1, 1)
    np.cumsum(new_indptr, out=new_indptr)
    return new_indptr, cd.astype(np.int32)


def louvain(indptr, indices, levels: int = 2, seed: int = 0) -> np.ndarray:
    """Returns community id per node (int32, compacted)."""
    rng = np.random.default_rng((seed, 0))  # salt 0: legacy stream slot
    N = len(indptr) - 1
    comm = np.arange(N, dtype=np.int32)
    comm, _ = _local_moving(indptr, indices, comm, rng=rng)
    comm, n1 = _compress(comm)
    for _ in range(levels - 1):
        aggr_ptr, aggr_idx = _aggregate(indptr, indices, comm, n1)
        meta = np.arange(n1, dtype=np.int32)
        meta, improved = _local_moving(aggr_ptr, aggr_idx, meta, rng=rng)
        meta, n2 = _compress(meta)
        if not improved or n2 == n1:
            break
        comm = meta[comm]
        n1 = n2
    return comm


def modularity(indptr, indices, comm) -> float:
    deg = np.diff(indptr).astype(np.float64)
    two_m = deg.sum()
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    intra = (comm[src] == comm[indices]).sum() / two_m
    sigma = np.zeros(comm.max() + 1, np.float64)
    np.add.at(sigma, comm, deg)
    expected = np.sum((sigma / two_m) ** 2)
    return float(intra - expected)
