"""Distributed feature access with a *static halo budget* (DESIGN.md §2).

The feature table is sharded into community-contiguous node ranges (one per
device on the `shard` mesh axis). A batch gather splits into:

  local     — rows this device owns (HBM gather only)
  halo      — rows owned by the ±`halo` neighboring shards: fixed-size
              (r_cap) request/response exchanges over collective_permute
  global    — fallback: all-gather every request id, every shard serves its
              rows, psum_scatter returns them (what a structure-agnostic
              policy requires)

COMM-RAND's community-aligned batches keep nearly all accesses in
local+halo, so the collective roofline term scales with `2*halo*r_cap*F`
instead of `D*K*F` — the pod-scale analogue of the paper's cache story.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


def owner_of(ids, n_per_shard):
    return ids // n_per_shard


def _axis_size(axis: str) -> int:
    # jax.lax.axis_size only exists on newer jax; psum(1) is the portable
    # spelling (constant-folded, no collective is emitted)
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


def halo_gather(feats_local, ids, *, n_per_shard: int, r_cap: int,
                halo: int, axis: str = "shard"):
    """Inside shard_map. feats_local: (Ns, F); ids: (K,) global node ids
    (sentinel >= N allowed -> zero rows). Returns ((K, F), dropped_count).

    Remote ids beyond ±halo shards are DROPPED (zero rows) and counted —
    calibration must pick (halo, r_cap) so this is negligible for the
    policy in use.
    """
    D = _axis_size(axis)
    me = lax.axis_index(axis)
    K = ids.shape[0]
    F = feats_local.shape[1]
    n_total = n_per_shard * D
    valid = ids < n_total
    owner = jnp.where(valid, owner_of(ids, n_per_shard), D)

    out = jnp.zeros((K, F), feats_local.dtype)
    loc = owner == me
    lidx = jnp.where(loc, ids - me * n_per_shard, 0)
    out = out + jnp.where(loc[:, None], feats_local[lidx], 0)
    served = loc

    for h in range(1, halo + 1):
        # at h == D/2 both directions reach the SAME shard — visiting it
        # twice would serve (and double) every row it owns
        for sign in ((1,) if (2 * h) % D == 0 else (1, -1)):
            tgt = (me + sign * h) % D
            want = owner == tgt
            # up to r_cap request slots for this neighbor
            pos = jnp.argsort(~want)[:r_cap]
            pvalid = want[pos]
            req = jnp.where(pvalid, ids[pos] - tgt * n_per_shard, 0)
            fwd = [(i, (i + sign * h) % D) for i in range(D)]
            rev = [(i, (i - sign * h) % D) for i in range(D)]
            got_req = lax.ppermute(req, axis, perm=fwd)
            got_val = lax.ppermute(pvalid, axis, perm=fwd)
            rows = feats_local[jnp.clip(got_req, 0, feats_local.shape[0] - 1)]
            rows = rows * got_val[:, None].astype(rows.dtype)
            back = lax.ppermute(rows, axis, perm=rev)
            out = out.at[pos].add(
                jnp.where(pvalid[:, None], back, 0))
            served = served | (want & jnp.zeros_like(want).at[pos].set(
                pvalid, mode="drop"))

    dropped = jnp.sum(valid & ~served)
    return out, dropped


def global_gather(feats_local, ids, *, n_per_shard: int,
                  axis: str = "shard", chunk: int = 32768):
    """All-to-all fallback: every shard serves every device's requests.
    Collective bytes ~ D * K * F — the structure-agnostic cost. Requests are
    served in `chunk`-sized waves to bound the (D, chunk, F) exchange
    buffer."""
    D = _axis_size(axis)
    me = lax.axis_index(axis)
    n_total = n_per_shard * D
    K = ids.shape[0]
    chunk = min(chunk, K)
    n_chunks = (K + chunk - 1) // chunk
    pad = n_chunks * chunk - K
    ids = jnp.pad(ids, (0, pad), constant_values=n_total)

    def serve(ids_c):
        all_ids = lax.all_gather(ids_c, axis)            # (D, Kc)
        all_owner = jnp.where(all_ids < n_total,
                              owner_of(all_ids, n_per_shard), D)
        mine = all_owner == me
        lidx = jnp.where(mine, all_ids - me * n_per_shard, 0)
        contrib = feats_local[lidx] * mine[..., None].astype(
            feats_local.dtype)
        return lax.psum_scatter(contrib, axis, scatter_dimension=0)

    out = lax.map(serve, ids.reshape(n_chunks, chunk))
    out = out.reshape(n_chunks * chunk, -1)[:K]
    return out, jnp.zeros((), jnp.int32)


def gather_for_policy(feats_local, ids, *, n_per_shard, r_cap, halo,
                      axis="shard", mode="halo"):
    if mode == "halo":
        return halo_gather(feats_local, ids, n_per_shard=n_per_shard,
                           r_cap=r_cap, halo=halo, axis=axis)
    return global_gather(feats_local, ids, n_per_shard=n_per_shard,
                         axis=axis)


def halo_gather_np(feats_shards, ids_shards, *, n_per_shard: int,
                   r_cap: int, halo: int):
    """Host-side mirror of `halo_gather` simulating ALL D shards at once.

    feats_shards: (D, Ns, F); ids_shards: (D, K) global node ids (sentinel
    >= Ns*D -> zero rows). Returns ((D, K, F) rows, (D,) dropped counts),
    step-for-step identical to the on-device exchange — including the
    stable argsort request packing and the r_cap truncation — so property
    tests can sweep random graphs without spawning a device mesh, and one
    subprocess test pins this mirror `==` the `shard_map` path.
    """
    import numpy as np

    feats = np.asarray(feats_shards)
    ids = np.asarray(ids_shards)
    D, Ns, F = feats.shape
    K = ids.shape[1]
    n_total = Ns * D
    valid = ids < n_total
    owner = np.where(valid, ids // n_per_shard, D)

    out = np.zeros((D, K, F), feats.dtype)
    served = np.zeros((D, K), bool)
    for me in range(D):
        loc = owner[me] == me
        lidx = np.where(loc, ids[me] - me * Ns, 0)
        out[me] += np.where(loc[:, None], feats[me][lidx], 0)
        served[me] = loc

    for h in range(1, halo + 1):
        # mirror of the device loop's h == D/2 dedup
        for sign in ((1,) if (2 * h) % D == 0 else (1, -1)):
            # every device's request packet for its (me + sign*h) neighbor
            reqs = np.zeros((D, r_cap), np.int64)
            pvalids = np.zeros((D, r_cap), bool)
            poss = np.zeros((D, r_cap), np.int64)
            for me in range(D):
                tgt = (me + sign * h) % D
                want = owner[me] == tgt
                pos = np.argsort(~want, kind="stable")[:r_cap]
                pvalid = want[pos]
                reqs[me] = np.where(pvalid, ids[me][pos] - tgt * Ns, 0)
                pvalids[me] = pvalid
                poss[me] = pos
            for me in range(D):
                # ppermute fwd delivers device src's packet to
                # (src + sign*h) % D — i.e. `me` receives from src below
                src = (me - sign * h) % D
                got_req, got_val = reqs[src], pvalids[src]
                rows = feats[me][np.clip(got_req, 0, Ns - 1)]
                rows = rows * got_val[:, None].astype(rows.dtype)
                # rev returns the served rows to src
                back, pvalid, pos = rows, pvalids[src], poss[src]
                np.add.at(out[src], pos,
                          np.where(pvalid[:, None], back, 0))
                served[src][pos[pvalid]] = True

    dropped = np.sum(valid & ~served, axis=1)
    return out, dropped


def collective_bytes_model(K: int, F: int, D: int, r_cap: int, halo: int,
                           mode: str, itemsize: int = 4) -> int:
    """Napkin model used by the §Roofline analysis and tests."""
    if mode == "halo":
        return 2 * halo * r_cap * (F * itemsize + 8)
    return D * K * 4 + K * F * itemsize * 2     # ids all-gather + psum_scatter
