"""Roofline analysis over the dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh) cell, per chip-second:

    compute    = analytic_FLOPs / chips / 197 TF/s      (bf16 peak)
    memory     = analytic_HBM_bytes_per_chip / 819 GB/s
    collective = loop-corrected HLO collective bytes / 50 GB/s/link

Why analytic compute/memory: XLA ``cost_analysis`` counts while-loop bodies
once, so scan-over-layers programs under-report by ~L x (the ``hlo/ana``
column shows the measured-to-analytic ratio — it sits near 1/L for train
cells, confirming the correction). Collectives come from the partitioned
HLO with nested trip-count multipliers (dryrun.collective_bytes), so the
real compiler schedule — not a guess — feeds the dominant-term analysis.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16] [--csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.launch.analytic import cell_cost
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")


def analyse(meta: dict, chips: int) -> dict:
    shape = SHAPES[meta["shape"]]
    cfg = get_config(meta["arch"])
    cost = cell_cost(cfg, shape, chips)
    co = meta["collective_bytes_per_device"]["total"]
    t_c = cost.flops_global / chips / PEAK_FLOPS_BF16
    t_m = cost.hbm_bytes_per_device / HBM_BW
    t_i = co / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_i),
              key=lambda kv: kv[1])
    hlo_ratio = (meta["flops_per_device"] * chips / cost.flops_global
                 if cost.flops_global else 0.0)
    return {
        "arch": meta["arch"], "shape": meta["shape"], "mesh": meta["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_i,
        "bottleneck": dom[0],
        "roofline_fraction": t_c / dom[1] if dom[1] > 0 else 0.0,
        "useful_flops_fraction": cost.model_flops / cost.flops_global,
        "hlo_to_analytic": hlo_ratio,
        "mem_gib": (meta["memory"]["argument_bytes"] +
                    meta["memory"]["temp_bytes"]) / 2**30,
    }


def load_all(art_dir: str = ART_DIR, mesh: str = None):
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            meta = json.load(f)
        if "skipped" in meta:
            continue
        if meta.get("kind") == "gnn-train" or meta["arch"].startswith("gnn"):
            continue    # GNN cells reported separately (§Dry-run)
        if mesh and meta["mesh"] != mesh:
            continue
        chips = {"16x16": 256, "2x16x16": 512}.get(meta["mesh"], 256)
        rows.append(analyse(meta, chips))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def skipped_cells(art_dir: str = ART_DIR, mesh: str = None):
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            meta = json.load(f)
        if "skipped" in meta:
            tag = os.path.basename(path)[:-5]
            if mesh is None or tag.endswith(mesh):
                out.append((tag, meta["skipped"]))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--art-dir", default=ART_DIR)
    args = ap.parse_args()
    rows = load_all(args.art_dir, args.mesh)
    if args.csv:
        print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
              "bottleneck,roofline_fraction,useful_flops_fraction,"
              "hlo_to_analytic,mem_gib")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{r['t_compute_s']:.4e},{r['t_memory_s']:.4e},"
                  f"{r['t_collective_s']:.4e},{r['bottleneck']},"
                  f"{r['roofline_fraction']:.3f},"
                  f"{r['useful_flops_fraction']:.3f},"
                  f"{r['hlo_to_analytic']:.3f},{r['mem_gib']:.2f}")
    else:
        print(f"{'arch':22s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
              f"{'collect':>10s} {'bound':>10s} {'roof%':>6s} "
              f"{'useful%':>8s} {'hlo/ana':>8s} {'GiB':>7s}")
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:12s} "
                  f"{r['t_compute_s']:10.3e} {r['t_memory_s']:10.3e} "
                  f"{r['t_collective_s']:10.3e} {r['bottleneck']:>10s} "
                  f"{100 * r['roofline_fraction']:6.1f} "
                  f"{100 * r['useful_flops_fraction']:8.1f} "
                  f"{r['hlo_to_analytic']:8.3f} {r['mem_gib']:7.2f}")
    for tag, why in skipped_cells(args.art_dir, args.mesh):
        print(f"SKIP {tag}: {why}")


if __name__ == "__main__":
    main()
