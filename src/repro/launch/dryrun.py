import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (device count locks on
# first init). Everything else follows.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import numpy as np   # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import (SHAPES, TrainConfig, long_context_ok)  # noqa: E402
from repro.configs.registry import LM_ARCHS, get_config  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.launch import specs as specs_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.lm import transformer  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.train.train_step import (make_decode_step, make_prefill_step,  # noqa: E402
                                    make_train_step)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

_COLL_RE = re.compile(
    r"=\s+(\S+?)\[([0-9,]*)\]\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP1D_RE = re.compile(r"replica_groups=\[(\d+)\]<=")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16}


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _loop_multipliers(hlo_text: str):
    """Map computation-name -> execution multiplier, accounting for nested
    `while` loops (XLA cost analysis counts loop bodies ONCE; jax scans
    lower to while loops whose trip count appears as the constant in the
    loop condition)."""
    comp_of = {}          # comp name -> list of its lines
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COMP_RE.match(s)
        if m and s.endswith("{"):
            cur = m.group(1)
            comp_of[cur] = []
        elif cur is not None:
            comp_of[cur].append(line)

    def cond_trip(cond_lines):
        """Trip count = the constant operand of the ROOT compare (taking a
        max over all constants grabs unrelated bounds)."""
        const_of = {}
        for cl in cond_lines:
            mm = re.search(r"%([\w\.\-]+) = s32\[\]\{?:?\S*\}? ?constant\((\d+)\)", cl)
            if mm:
                const_of[mm.group(1)] = int(mm.group(2))
        for cl in cond_lines:
            if "ROOT" in cl and "compare(" in cl:
                for o in re.findall(r"%([\w\.\-]+)", cl):
                    if o in const_of:
                        return const_of[o]
        # fallback: XLA may inline the bound via a known_trip_count config
        return 1

    trip_of_body = {}
    parent_of_body = {}
    for comp, lines in comp_of.items():
        for line in lines:
            w = _WHILE_RE.search(line)
            if not w:
                continue
            cond, body = w.group(1), w.group(2)
            trip = cond_trip(comp_of.get(cond, []))
            tc = re.search(r'known_trip_count[":{]+n[":]+(\d+)', line)
            if tc:
                trip = int(tc.group(1))
            trip_of_body[body] = trip
            parent_of_body[body] = comp

    def mult(comp, depth=0):
        if depth > 16 or comp not in trip_of_body:
            return 1.0
        return trip_of_body[comp] * mult(parent_of_body.get(comp, ""),
                                         depth + 1)

    return comp_of, {c: mult(c) for c in comp_of}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic from the partitioned HLO, with nested
    while-loop trip-count multipliers (FSDP weight all-gathers live inside
    the layer scan and execute L times; counting them once underestimates
    the collective term by ~L).

    Shapes in the SPMD module are per-device local. Model (ring):
      all-gather          -> result_bytes        (received)
      all-reduce          -> 2 * operand_bytes   (reduce-scatter + all-gather)
      reduce-scatter      -> result_bytes * group (operand sent)
      all-to-all/permute  -> result_bytes
    """
    comp_of, mults = _loop_multipliers(hlo_text)
    per_op = {}
    total = 0.0
    for comp, lines in comp_of.items():
        mult = mults.get(comp, 1.0)
        for line in lines:
            m = _COLL_RE.search(line)
            if m is None:
                continue
            dtype, dims, op = m.group(1), m.group(2), m.group(3)
            nbytes = _DTYPE_BYTES.get(dtype, 4)
            for d in dims.split(","):
                if d:
                    nbytes *= int(d)
            g = _GROUP_RE.search(line)
            if g:
                group = int(g.group(2))
            else:
                g1 = _GROUP1D_RE.search(line)
                group = int(g1.group(1)) if g1 else 16
            if op == "all-reduce":
                b = 2.0 * nbytes
            elif op == "reduce-scatter":
                b = float(nbytes) * group
            else:
                b = float(nbytes)
            per_op[op] = per_op.get(op, 0.0) + b * mult
            total += b * mult
    per_op["total"] = total
    return per_op


def _sds_with(tree, spec_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree, spec_tree)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               tcfg: TrainConfig = None):
    """Lower + compile one (arch x shape x mesh) cell.

    Returns (compiled, lowered, meta) — raises on any sharding/compile bug.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not long_context_ok(cfg):
        return None, None, {"skipped": "full-attention arch: long_500k needs "
                                       "sub-quadratic attention (DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    tcfg = tcfg or TrainConfig()
    max_seq = 32768 if cfg.learned_pos else 4096
    aparams = transformer.abstract_params(cfg, max_seq=max_seq)
    pspec = shd.param_specs(aparams, mesh)
    params_in = _sds_with(aparams, pspec, mesh)

    t0 = time.time()
    if shape.kind == "train":
        step, _ = make_train_step(cfg, tcfg, mesh)
        aopt = jax.eval_shape(adamw.init, aparams)
        ospec = {"m": pspec, "v": pspec, "count": P()}
        opt_in = _sds_with(aopt, ospec, mesh)
        batch = specs_mod.train_batch_specs(cfg, shape.global_batch,
                                            shape.seq_len)
        batch_in = _sds_with(batch, shd.batch_specs(batch, mesh, "fsdp"),
                             mesh)
        lowered = step.lower(params_in, opt_in, batch_in)
    elif shape.kind == "prefill":
        batch = specs_mod.prefill_batch_specs(cfg, shape.global_batch,
                                              shape.seq_len)
        batch_in = _sds_with(batch, shd.batch_specs(batch, mesh, "tp_sp"),
                             mesh)
        acache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, shape.global_batch,
                                           shape.seq_len, jnp.bfloat16))
        cspec = shd.cache_specs(acache, mesh)
        # jit with cache out_shardings so the cache is not replicated
        from repro.train.train_step import _with_mesh_ctx
        fn = _with_mesh_ctx(mesh, lambda p, b: transformer.prefill(cfg, p, b),
                            "tp_sp")
        logits_spec = P(shd.ShardCtx(mesh, "tp_sp").batch_axes, None,
                        "model")
        step = jax.jit(fn, out_shardings=(
            NamedSharding(mesh, logits_spec),
            shd.to_shardings(cspec, mesh)))
        lowered = step.lower(params_in, batch_in)
    else:  # decode
        acache, tok_s, pos_s = specs_mod.decode_specs(
            cfg, shape.global_batch, shape.seq_len)
        cspec = shd.cache_specs(acache, mesh)
        cache_in = _sds_with(acache, cspec, mesh)
        tok_in = _sds_with(tok_s, shd.batch_specs(tok_s, mesh, "tp_sp"),
                           mesh)
        pos_in = jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P()))
        from repro.train.train_step import _with_mesh_ctx
        fn = _with_mesh_ctx(
            mesh, lambda p, c, t, i: transformer.decode_step(cfg, p, c, t, i),
            "tp_sp")
        logits_spec = P(shd.ShardCtx(mesh, "tp_sp").batch_axes
                        if shape.global_batch % 32 == 0 else None,
                        None, "model")
        step = jax.jit(fn, donate_argnums=(1,), out_shardings=(
            NamedSharding(mesh, logits_spec),
            shd.to_shardings(cspec, mesh)))
        lowered = step.lower(params_in, cache_in, tok_in, pos_in)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # jax 0.4.x returns [dict] per module
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "param_count": int(sum(
            int(np.prod(np.asarray(x.shape, dtype=np.int64)))
            for x in jax.tree.leaves(aparams))),
    }
    return compiled, lowered, meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()

    archs = list(LM_ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                try:
                    compiled, lowered, meta = lower_cell(
                        arch, shape, multi_pod=mp)
                    if compiled is None:
                        print(f"SKIP {tag}: {meta['skipped']}")
                    else:
                        mem = meta["memory"]
                        per_dev_gib = (mem["argument_bytes"] +
                                       mem["temp_bytes"]) / 2**30
                        print(f"OK   {tag}: compile={meta['t_compile_s']}s "
                              f"flops/dev={meta['flops_per_device']:.3e} "
                              f"mem/dev={per_dev_gib:.2f}GiB "
                              f"coll/dev={meta['collective_bytes_per_device']['total']:.3e}B")
                    path = os.path.join(args.out, tag + ".json")
                    with open(path, "w") as f:
                        json.dump(meta, f, indent=1)
                    del compiled, lowered
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:300]}")
                    failures.append(tag)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
