"""Analytic (napkin) FLOP / HBM-byte model per (arch x shape) cell.

Why this exists: XLA's ``cost_analysis()`` counts a ``while`` body ONCE, so
scan-over-layers programs under-report FLOPs/bytes by ~L x. Collectives are
corrected by the loop-aware HLO parse (dryrun.collective_bytes); compute and
memory use this analytic model, cross-checked against the HLO numbers (the
HLO value divided by the loop undercount ratio should land within ~2x).

Conventions (documented in EXPERIMENTS.md §Roofline):
  train   matmul FLOPs = 8 * N_active * D   (6ND + one remat re-forward)
  prefill matmul FLOPs = 2 * N_active * D
  decode  matmul FLOPs = 2 * N_active * B
  attention, WKV, logits terms added explicitly; MODEL_FLOPS (the "useful"
  numerator) stays the classic 6ND / 2ND.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class CellCost:
    flops_global: float
    hbm_bytes_per_device: float
    model_flops: float           # 6ND / 2ND "useful" numerator


def _layer_params(cfg: ModelConfig):
    """(dense per-decoder-layer active, moe total extra, encoder per-layer)."""
    d = cfg.d_model
    attn = d * cfg.q_dim * 2 + d * cfg.kv_dim * 2
    if cfg.rwkv:
        dense = d * cfg.q_dim * 5 + 2 * d * cfg.d_ff + d * d
        return dense, 0, 0
    if cfg.moe:
        act = attn + 3 * d * cfg.moe_d_ff * cfg.top_k + \
            (3 * d * cfg.shared_d_ff if cfg.shared_d_ff else 0)
        extra = 3 * d * cfg.moe_d_ff * (cfg.num_experts - cfg.top_k)
        return act, extra, 0
    mlp = (2 if cfg.mlp_bias else 3) * d * cfg.d_ff
    enc = (attn + mlp) if cfg.encoder_decoder else 0
    dec = attn + mlp + (attn if cfg.encoder_decoder else 0)  # + cross-attn
    return dec, 0, enc


def n_active(cfg: ModelConfig) -> int:
    dec, _, enc = _layer_params(cfg)
    return dec * cfg.num_layers + enc * cfg.num_encoder_layers


def n_total(cfg: ModelConfig) -> int:
    dec, extra, enc = _layer_params(cfg)
    return (dec + extra) * cfg.num_layers + enc * cfg.num_encoder_layers


def _attn_flops_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    """Causal flash forward over all layers (window-aware)."""
    tot = 0.0
    for i in range(cfg.num_layers):
        s_eff = S if cfg.is_global_layer(i) else min(S, cfg.window)
        tot += 2.0 * B * S * s_eff * cfg.num_heads * cfg.head_dim
    if cfg.encoder_decoder:
        E = cfg.encoder_seq
        tot += cfg.num_encoder_layers * 4.0 * B * E * E * cfg.num_heads * \
            cfg.head_dim
        tot += cfg.num_layers * 4.0 * B * S * E * cfg.num_heads * cfg.head_dim
    if cfg.rwkv:
        C, N, H = 16, cfg.head_dim, cfg.num_heads
        tot += B * S * H * (4.0 * C * N + 6.0 * N * N)
    if cfg.hybrid:
        tot += B * S * cfg.d_model * cfg.ssm_state * 6.0
    return tot


def cell_cost(cfg: ModelConfig, shape: ShapeConfig, chips: int) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    D = B * S
    V, d = cfg.padded_vocab, cfg.d_model
    na, nt = n_active(cfg), n_total(cfg)
    tp = 16
    L = cfg.num_layers + cfg.num_encoder_layers

    if shape.kind == "train":
        flops = 8.0 * na * D + 4.5 * _attn_flops_fwd(cfg, B, S) \
            + 8.0 * D * d * V
        model = 6.0 * na * D
        hbm = (28.0 * nt / chips                     # adamw state traffic
               + 3 * 2.0 * nt / tp                    # weight passes (bf16)
               + 6.0 * L * D * d * 2 / chips          # activations
               + 3.0 * D * V * 2 / chips)             # CE logits chunks
    elif shape.kind == "prefill":
        flops = 2.0 * na * D + _attn_flops_fwd(cfg, B, S) + 2.0 * B * d * V
        model = 2.0 * na * D
        hbm = (2.0 * nt / tp
               + 2.0 * L * D * d * 2 / chips
               + 2.0 * cfg.num_layers * D * cfg.kv_dim * 2 * 2 / chips)
    else:  # decode: one token per sequence, full-context attention
        attn = 0.0
        for i in range(cfg.num_layers):
            s_eff = S if cfg.is_global_layer(i) else min(S, cfg.window)
            if cfg.rwkv:
                s_eff = 0
            attn += 4.0 * B * s_eff * cfg.num_heads * cfg.head_dim
        if cfg.rwkv:
            attn += 6.0 * B * cfg.num_heads * cfg.head_dim ** 2 * \
                cfg.num_layers
        flops = 2.0 * na * B + attn + 2.0 * B * d * V
        model = 2.0 * na * B
        cache = 0.0
        for i in range(cfg.num_layers):
            s_eff = 0 if cfg.rwkv else \
                (S if cfg.is_global_layer(i) else min(S, cfg.window))
            cache += 2.0 * B * s_eff * cfg.kv_dim * 2
        hbm = 2.0 * nt / tp + cache / chips + 2.0 * B * d * V / chips
    return CellCost(flops, hbm, model)
