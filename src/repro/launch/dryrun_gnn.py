import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede any jax import (see dryrun.py).

import argparse    # noqa: E402
import json        # noqa: E402

import numpy as np  # noqa: E402
import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import GNNConfig, TrainConfig  # noqa: E402
from repro.core import halo as halo_mod  # noqa: E402
from repro.core.minibatch import Block, MiniBatch  # noqa: E402
from repro.launch.dryrun import ART_DIR, collective_bytes  # noqa: E402
from repro.models.gnn.models import apply_gnn, init_gnn  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.train.losses import gnn_softmax_ce  # noqa: E402

"""Pod-scale GNN dry-run: the paper's pipeline at ogbn-papers100M scale.

Topology stays on hosts (DGL-style CPU sampling; DESIGN.md §4) — the device
step consumes prebuilt MiniBatch index towers. The feature table (111M x 128
fp32) is sharded into community-contiguous ranges over a `shard` axis; the
batch feature gather runs through `core.halo` (halo budget for COMM-RAND,
global fallback for RAND), then the SAGE tower + AdamW.

Static caps per policy come from calibration on the papers-like synthetic
graph (see EXPERIMENTS.md §Dry-run), scaled to papers100M's fanout tree.
"""

N_NODES = 111_059_956
FEAT_DIM = 128
NUM_CLASSES = 172
ROOTS_PER_DEV = 1024
FANOUTS = (10, 10, 10)

# calibrated caps (unique nodes per level, per device batch)
POLICY_CELLS = {
    # policy          caps                              r_cap   halo  mode
    "rand_p05":   ((1024, 11264, 109568, 875520),       0,      0,  "global"),
    "commrand_mix125_p10": ((1024, 8192, 24576, 53248), 8192,   2,  "halo"),
    "norand_p10": ((1024, 6144, 16384, 32768),          4096,   1,  "halo"),
    # §Perf hillclimb: tighter halo budget from p99.5 (vs max) calibration —
    # trades <0.5% dropped halo rows for a 2.4x smaller exchange
    "commrand_mix125_p10_tuned": ((1024, 8192, 24576, 53248), 3456, 2,
                                  "halo"),
}


def gnn_mesh(multi_pod: bool):
    devs = jax.devices()
    if multi_pod:
        return Mesh(np.asarray(devs[:512]).reshape(2, 256), ("pod", "shard"))
    return Mesh(np.asarray(devs[:256]).reshape(256,), ("shard",))


def batch_specs(caps, n_dev_total):
    """Per-DEVICE MiniBatch tower specs, with a leading device-batch dim that
    shards over ('pod','shard')."""
    def sds(shape, dtype):
        return jax.ShapeDtypeStruct((n_dev_total,) + shape, dtype)

    levels = [sds((c,), jnp.int32) for c in (ROOTS_PER_DEV,) + caps]
    blocks = []
    dims = (ROOTS_PER_DEV,) + caps
    for h, r in enumerate(FANOUTS):
        blocks.append(Block(
            src_pos=sds((dims[h], r), jnp.int32),
            self_pos=sds((dims[h],), jnp.int32),
            edge_mask=sds((dims[h], r), jnp.bool_),
            dst_mask=sds((dims[h],), jnp.bool_),
        ))
    return MiniBatch(
        levels=levels,
        node_mask=sds((caps[-1],), jnp.bool_),
        blocks=blocks[::-1],
        labels=sds((ROOTS_PER_DEV,), jnp.int32),
        label_mask=sds((ROOTS_PER_DEV,), jnp.bool_),
    )


def lower_gnn_cell(policy_name: str, multi_pod: bool = False):
    caps, r_cap, halo_w, mode = POLICY_CELLS[policy_name]
    mesh = gnn_mesh(multi_pod)
    n_shard = mesh.shape["shard"]
    n_pod = mesh.shape.get("pod", 1)
    n_dev_total = n_shard * n_pod
    n_per_shard = (N_NODES + n_shard - 1) // n_shard
    n_pad = n_per_shard * n_shard

    cfg = GNNConfig("graphsage-papers100m", "sage", 3, 256, FEAT_DIM,
                    NUM_CLASSES, fanout=FANOUTS)
    tcfg = TrainConfig()
    aparams = jax.eval_shape(lambda k: init_gnn(cfg, k), jax.random.key(0))
    aopt = jax.eval_shape(adamw.init, aparams)

    feat_sharding = NamedSharding(mesh, P("shard", None))
    feats_in = jax.ShapeDtypeStruct((n_pad, FEAT_DIM), jnp.float32,
                                    sharding=feat_sharding)
    batch_axes = ("pod", "shard") if multi_pod else ("shard",)
    abatch = batch_specs(caps, n_dev_total)
    batch_in = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, P(batch_axes,
                                           *([None] * (len(s.shape) - 1))))),
        abatch)
    repl = NamedSharding(mesh, P())
    params_in = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=repl),
        aparams)
    opt_in = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=repl),
        aopt)

    in_specs_gather = (P("shard", None), P(batch_axes, None))
    out_specs_gather = (P(batch_axes, None, None), P(batch_axes))

    @partial_shard_map(mesh, in_specs_gather, out_specs_gather)
    def gather(feats_local, ids_b):
        x, dropped = halo_mod.gather_for_policy(
            feats_local, ids_b[0], n_per_shard=n_per_shard, r_cap=r_cap,
            halo=halo_w, axis="shard", mode=mode)
        return x[None], dropped[None]

    def train_step(params, opt_state, feats, batch: MiniBatch):
        def loss_fn(p):
            x, dropped = gather(feats, batch.node_ids)
            # per-device tower, batched over the device dim via vmap
            logits = jax.vmap(
                lambda bt, xd: apply_gnn(cfg, p, bt, xd, None))(
                batch, x.reshape(n_dev_total, caps[-1], FEAT_DIM))
            loss = jnp.mean(jax.vmap(
                lambda lg, lb, m: gnn_softmax_ce(lg, lb, m))(
                logits, batch.labels,
                batch.label_mask.astype(jnp.float32)))
            return loss, dropped.sum()

        (loss, dropped), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params2, opt2 = adamw.update(grads, opt_state, params,
                                     lr=tcfg.learning_rate,
                                     weight_decay=tcfg.weight_decay)
        return params2, opt2, {"loss": loss, "dropped": dropped}

    step = jax.jit(train_step, donate_argnums=(0, 1),
                   out_shardings=(jax.tree.map(lambda _: repl, aparams),
                                  jax.tree.map(lambda _: repl, aopt),
                                  {"loss": repl, "dropped": repl}))
    lowered = step.lower(params_in, opt_in, feats_in, batch_in)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    meta = {
        "arch": "graphsage-papers100m", "shape": policy_name,
        "mesh": "2x256" if multi_pod else "256", "kind": "gnn-train",
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll,
        "memory": {"argument_bytes": mem.argument_size_in_bytes,
                   "output_bytes": mem.output_size_in_bytes,
                   "temp_bytes": mem.temp_size_in_bytes,
                   "alias_bytes": mem.alias_size_in_bytes},
        "caps": caps, "r_cap": r_cap, "halo": halo_w, "gather_mode": mode,
        "halo_bytes_model": halo_mod.collective_bytes_model(
            caps[-1], FEAT_DIM, n_shard, r_cap, halo_w, mode),
    }
    return compiled, lowered, meta


def partial_shard_map(mesh, in_specs, out_specs):
    from repro.dist.sharding import shard_map

    def deco(f):
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)
    return deco


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    fails = []
    for mp in meshes:
        for pol in POLICY_CELLS:
            tag = f"gnn_{pol}_{'2x256' if mp else '256'}"
            try:
                compiled, lowered, meta = lower_gnn_cell(pol, mp)
                per_dev = (meta["memory"]["argument_bytes"] +
                           meta["memory"]["temp_bytes"]) / 2**30
                print(f"OK   {tag}: mem/dev={per_dev:.2f}GiB "
                      f"coll/dev={meta['collective_bytes_per_device']['total']:.3e}B "
                      f"flops/dev={meta['flops_per_device']:.3e}")
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(meta, f, indent=1)
                del compiled, lowered
            except Exception as e:  # noqa: BLE001
                import traceback
                traceback.print_exc()
                fails.append(tag)
    if fails:
        raise SystemExit(f"FAILURES: {fails}")
    print("gnn dry-run cells passed")


if __name__ == "__main__":
    main()
