"""Batched LM serving launcher: prefill a request batch, then decode with
per-step continuous metrics (tok/s, cache bytes).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
        --batch 8 --prompt-len 32 --tokens 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import LM_ARCHS, get_config
from repro.launch.specs import materialize, prefill_batch_specs
from repro.models.lm import transformer
from repro.train.train_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=list(LM_ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    total = args.prompt_len + args.tokens
    params = transformer.init(cfg, jax.random.key(args.seed),
                              max_seq=max(total, 64))
    batch = materialize(prefill_batch_specs(cfg, args.batch,
                                            args.prompt_len))
    batch["tokens"] = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size, jnp.int32)

    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)

    t0 = time.perf_counter()
    logits, pcache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_pf = time.perf_counter() - t0
    print(f"prefill: {args.batch} x {args.prompt_len} tok in "
          f"{t_pf * 1e3:.1f} ms "
          f"({args.batch * args.prompt_len / t_pf:.0f} tok/s)")

    cache = transformer.init_cache(cfg, args.batch, total, jnp.bfloat16)
    if not cfg.rwkv:
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], pcache["k"].astype(cache["k"].dtype), 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], pcache["v"].astype(cache["v"].dtype), 0, axis=2)
        for key in ("h", "conv", "ck", "cv"):
            if key in pcache:
                cache[key] = pcache[key].astype(cache[key].dtype)
    else:
        cache = jax.tree.map(lambda z, p: p.astype(z.dtype), cache, pcache)

    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    print(f"cache: {cache_bytes / 2**20:.1f} MiB "
          f"({'state' if cfg.rwkv else 'KV'})")

    key = jax.random.key(args.seed + 1)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for t in range(args.tokens):
        logits, cache = decode(params, cache, tok, args.prompt_len + t)
        if args.temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(
                k, logits[:, -1] / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(
                jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decode: {args.tokens} steps x {args.batch} seqs in "
          f"{dt * 1e3:.1f} ms ({args.tokens * args.batch / dt:.0f} tok/s, "
          f"{dt / args.tokens * 1e3:.2f} ms/step)")
    print("greedy ids, seq 0:", np.concatenate(out, 1)[0].tolist())


if __name__ == "__main__":
    main()
