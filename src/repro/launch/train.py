"""Unified training launcher: ``--arch <id>`` selects any assigned LM
architecture or a GNN model (the paper's pipeline).

    PYTHONPATH=src python -m repro.launch.train --arch graphsage \
        --dataset reddit-like --policy comm_rand --mix 0.125 --p 1.0
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b \
        --reduced --steps 100 --ckpt-dir /tmp/ck

LM archs run the fault-tolerant loop (checkpoint/resume, straggler monitor,
optional int8 grad compression). Full-size LM configs require a real
TPU/multi-host environment; ``--reduced`` runs the smoke-scale variant
anywhere. GNN archs train for real on CPU.
"""
from __future__ import annotations

import argparse

from repro.configs.base import CommRandPolicy, GNNConfig, TrainConfig
from repro.configs.registry import GNN_ARCHS, LM_ARCHS, get_config


def train_lm(args):
    from repro.data.pipeline import (BlockShuffler, LMStream,
                                     SyntheticTokens)
    from repro.train.lm_loop import LMTrainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(learning_rate=args.lr, remat=not args.reduced,
                       grad_compression=args.compress_grads,
                       microbatches=args.microbatches)
    corpus = SyntheticTokens(cfg.vocab_size, num_docs=4096,
                             doc_len=args.seq * 2)
    stream = LMStream(corpus, args.batch, args.seq,
                      BlockShuffler(corpus.num_docs, 64,
                                    mode=args.shuffle_mode))
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    tr = LMTrainer(cfg, tcfg, stream, ckpt_dir=args.ckpt_dir, mesh=mesh,
                   ckpt_every=args.ckpt_every)
    if tr.step:
        print(f"resumed from step {tr.step}")
    r = tr.run(args.steps)
    print(f"{args.arch}: steps={args.steps} loss {r['loss_first']:.4f} -> "
          f"{r['loss_last']:.4f} stragglers={r['straggler_fraction']:.1%}")


def train_gnn(args):
    from repro.core.reorder import prepare
    from repro.graphs import synthetic
    from repro.train.gnn_loop import GNNTrainer

    g = prepare(synthetic.load(args.dataset), oracle=args.oracle)
    base = get_config(args.arch)
    cfg = GNNConfig(f"{args.arch}-{args.dataset}", base.model,
                    base.num_layers, base.hidden_dim, g.feat_dim,
                    g.num_classes, fanout=base.fanout)
    pol = CommRandPolicy(args.policy, args.mix, args.p)
    tcfg = TrainConfig(batch_size=args.batch, max_epochs=args.epochs,
                       learning_rate=args.lr)
    print(f"{cfg.model} on {g.name}: {g.num_nodes} nodes, "
          f"{g.communities.max() + 1} communities, policy "
          f"{pol.describe()}")
    tr = GNNTrainer(g, cfg, tcfg, pol, seed=args.seed).warmup()
    res = tr.fit(verbose=True)
    print(f"val={res.val_acc:.4f} test={res.test_acc:.4f} "
          f"epochs={res.epochs_to_converge} "
          f"per_epoch={res.per_epoch_time_s:.2f}s total={res.total_time_s:.1f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list(LM_ARCHS) + list(GNN_ARCHS))
    # shared
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    # LM
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--shuffle-mode", default="block",
                    choices=["rand", "block", "none"])
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    # GNN
    ap.add_argument("--dataset", default="reddit-like")
    ap.add_argument("--policy", default="comm_rand",
                    choices=["rand", "norand", "comm_rand"])
    ap.add_argument("--mix", type=float, default=0.125)
    ap.add_argument("--p", type=float, default=1.0)
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--oracle", action="store_true",
                    help="use planted communities instead of Louvain")
    args = ap.parse_args()
    if args.arch in LM_ARCHS:
        args.batch = args.batch or 8
        train_lm(args)
    else:
        args.batch = args.batch or 1024
        train_gnn(args)


if __name__ == "__main__":
    main()
