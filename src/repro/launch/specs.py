"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation: these drive `.lower()` in the dry-run and shape logic
in benchmarks. Modality frontends are stubs per the assignment: whisper gets
precomputed conv-frontend frames, qwen2-vl gets precomputed patch embeddings
and (B, 3, S) M-RoPE positions.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    batch = {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
    }
    if cfg.encoder_decoder:
        batch["frames"] = SDS((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.vision_tokens:
        batch["vision_embeds"] = SDS((B, cfg.vision_tokens, cfg.d_model),
                                     jnp.bfloat16)
        batch["positions"] = SDS((B, 3, S), jnp.int32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, B: int, S: int) -> Dict[str, Any]:
    b = train_batch_specs(cfg, B, S)
    b.pop("labels")
    return b


def decode_specs(cfg: ModelConfig, B: int, S: int):
    """(tokens, pos) specs + abstract cache for one decode step at a full
    cache of length S."""
    from repro.models.lm import transformer
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S, jnp.bfloat16))
    tokens = SDS((B, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return cache, tokens, pos


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Returns (kind, specs...) for the cell."""
    if shape.kind == "train":
        return ("train", train_batch_specs(cfg, shape.global_batch,
                                           shape.seq_len))
    if shape.kind == "prefill":
        return ("prefill", prefill_batch_specs(cfg, shape.global_batch,
                                               shape.seq_len))
    return ("decode",) + decode_specs(cfg, shape.global_batch, shape.seq_len)


def materialize(specs, key=0):
    """Concrete random arrays matching `specs` (for smoke tests/benches)."""
    rng = jax.random.key(key)

    def make(s):
        nonlocal rng
        rng, k = jax.random.split(rng)
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jax.random.randint(k, s.shape, 0, 100, s.dtype)
        return jax.random.normal(k, s.shape, s.dtype)

    return jax.tree.map(make, specs)
