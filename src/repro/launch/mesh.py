"""Production mesh construction.

`make_production_mesh` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run launcher
sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before importing
jax; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "run via launch/dryrun.py which forces 512 host devices")
    return Mesh(np.asarray(devices[:need]).reshape(shape), axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"mesh {shape} needs {need} devices")
    return Mesh(np.asarray(devices[:need]).reshape(shape), axes)


def make_test_mesh(axes: Tuple[str, ...] = ("data", "model")) -> Optional[Mesh]:
    """Largest mesh the current process supports (1 device => (1, 1))."""
    n = len(jax.devices())
    if len(axes) == 2:
        a = 2 if n >= 2 else 1
        b = max(1, min(n // a, 4))
        return make_mesh((a, b) if a * b <= n else (1, 1), axes)
    return make_mesh((1,) * len(axes), axes)


# v5e hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~per exchange direction)
HBM_PER_CHIP = 16 * 2**30       # 16 GiB
