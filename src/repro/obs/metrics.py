"""`MetricsHub`: one registry for every runtime metric the stack emits.

Before `repro.obs`, each subsystem metered itself its own way: the
feature cache through `HitRateMeter`, recovery actions through
`ResilienceMeter`, step-time outliers through `StragglerMonitor` (wired
into the LM loop only), benchmarks through ad-hoc dicts. The hub absorbs
them behind one registry of three primitive types:

  Counter    monotonically increasing int (cache hits, skipped steps)
  Gauge      last-written value (straggler fraction, current lr)
  Histogram  value distribution with count/sum/min/max + percentiles
             (step dispatch times)

The legacy meters keep their exact public behaviour — every existing
test and consumer is untouched — but accept an optional `hub=`; when
attached, every mutation mirrors into canonically named hub series
("cache/hits", "resilience/rollbacks", "straggler/fraction", ...), and
tests pin that the mirrored values equal the meter's own on a real
training run (meter-absorption equivalence).

Per-epoch snapshots: `mark_epoch(epoch)` closes a window — the deltas of
every counter since the previous mark plus current gauge values — and
appends it to `hub.epochs`, giving the per-epoch trajectory exporters
and the trace analyzer join against.

Export schema (versioned — consumers check `schema`): `export()` returns
`{"schema": OBS_SCHEMA_VERSION, "meta": run_metadata(), "metrics": ...,
"epochs": [...]}`. `run_metadata()` is also the shared run-metadata
header every `BENCH_*.json` artifact carries (schema version, backend,
jax version, git commit, hostname) so benchmark numbers are attributable
to the code + machine that produced them.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
from typing import Dict, List, Optional

OBS_SCHEMA_VERSION = 1


def run_metadata() -> Dict:
    """The shared run-metadata header: who/what/where produced an
    artifact. Keys are stable (CI asserts their presence in every
    BENCH_*.json): schema, backend, jax, git_commit, hostname, python."""
    import jax
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        commit = ""
    return {"schema": OBS_SCHEMA_VERSION,
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "git_commit": commit or "unknown",
            "hostname": socket.gethostname(),
            "python": sys.version.split()[0]}


class Counter:
    """Monotonic counter. `inc` rejects negative deltas — a decreasing
    'counter' is a gauge and would silently corrupt per-epoch deltas."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative inc {n}")
        self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Keeps every observation (runs here are bounded: one value per
    step or epoch) and summarizes with exact percentiles."""
    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return float(sum(self.values))

    def percentile(self, q: float) -> float:
        """Exact nearest-rank percentile (q in [0, 100]) — 0 when empty."""
        if not self.values:
            return 0.0
        vs = sorted(self.values)
        idx = min(len(vs) - 1, max(0, round(q / 100.0 * (len(vs) - 1))))
        return vs[idx]

    def summary(self) -> Dict:
        if not self.values:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {"count": self.count, "sum": self.sum,
                "min": min(self.values), "max": max(self.values),
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}


class MetricsHub:
    """Get-or-create registry of named metrics + per-epoch snapshots.

    A name is bound to ONE type for the lifetime of the hub: asking for
    `counter("x")` after `gauge("x")` raises instead of shadowing."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self.epochs: List[Dict] = []
        self._epoch_mark: Dict[str, int] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"requested {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    # -- snapshots ----------------------------------------------------------
    def snapshot(self) -> Dict:
        """Current value of every metric (histograms summarized)."""
        out = {}
        for name, m in sorted(self._metrics.items()):
            out[name] = m.summary() if isinstance(m, Histogram) \
                else m.value
        return out

    def mark_epoch(self, epoch: int) -> Dict:
        """Close the per-epoch window: counter DELTAS since the previous
        mark, current gauges, and histogram summaries. Appends (and
        returns) the entry on `self.epochs`."""
        entry: Dict = {"epoch": int(epoch)}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                entry[name] = m.value - self._epoch_mark.get(name, 0)
                self._epoch_mark[name] = m.value
            elif isinstance(m, Gauge):
                entry[name] = m.value
            else:
                entry[name] = m.summary()
        self.epochs.append(entry)
        return entry

    def export(self, extra: Optional[Dict] = None) -> Dict:
        """Versioned JSONL/BENCH-ready export of the whole hub."""
        out = {"schema": OBS_SCHEMA_VERSION, "meta": run_metadata(),
               "metrics": self.snapshot(), "epochs": list(self.epochs)}
        if extra:
            out.update(extra)
        return out
