"""Structured span tracing for the GNN training stack (`repro.obs`).

Every instrumented subsystem (batch builder, producer thread, trainer
step loop, checkpointing, cache refill) emits *spans* — named wall-clock
intervals tagged with a category and free-form args — into one global
`Tracer`. The on-disk format is Chrome-trace/Perfetto-compatible JSONL:
one JSON event object per line, each a complete-duration ("ph": "X") or
instant ("ph": "i") event with microsecond timestamps and real
pid/tid, so a trace answers "what was the producer thread doing while
the train step stalled?" by inspection. `python -m repro.obs` converts
a trace to the `{"traceEvents": [...]}` wrapper ui.perfetto.dev opens
directly, and computes overlap/stall reports from it (`obs/report.py`).

Span taxonomy (categories):

  step      consumer train-step dispatch (`GNNTrainer._train_one`)
  build     fused device batch build / epoch-order refresh
            (`pipeline.builder`)
  producer  the async producer thread's build loop
            (`pipeline.prefetch._produce`)
  wait      blocked time: consumer queue get, producer queue put
  sync      host<->device synchronization points (epoch-boundary flush,
            guard skip-counter sync, cache-refill churn sync,
            checkpoint save) — the analyzer gates that NONE of these
            occur mid-epoch
  device    accumulated device step timing (`DeviceStepTimer`)
  cache     dynamic-cache CLOCK refill dispatch
  ckpt      checkpoint restore / rollback
  loop      epoch envelope (`run_epoch`)
  eval      evaluation pass

Zero-cost when disabled: the module-level tracer defaults to None and
`span()`/`instant()` return a shared no-op context manager without
allocating — the hot path pays one global read and one `is None` test.
Tracing never syncs the device and never touches RNG or batch data, so
the loss trajectory is bit-identical with tracing on vs off (pinned by
tests/test_obs.py).

Device step timing — sync-free by construction: the trainer cannot time
individual device steps without a per-step `block_until_ready` (exactly
what the `no-host-sync-in-hot-path` lint forbids). Instead
`DeviceStepTimer.note` accumulates per-step host dispatch timestamps
(plus a handle on the step's un-synced output array), and `flush` —
called only at the EXISTING epoch/checkpoint boundary syncs, after the
boundary's own `block_until_ready` has drained the device — closes the
accumulated window into one "device_steps" span with per-step mean
duration in its args. No new boundary syncs, no mid-epoch syncs; the
jaxpr audit and lint stay clean because the timer never calls a sync
primitive itself.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

TRACE_SCHEMA_VERSION = 1

# required keys of every emitted event; "X" events additionally carry
# "dur" — the conformance contract tests/test_obs.py pins
EVENT_KEYS = ("name", "cat", "ph", "ts", "pid", "tid")


def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


class _Span:
    """One in-flight "X" (complete) event; also the reusable context
    manager `Tracer.span` returns."""
    __slots__ = ("_tracer", "_ev", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self._ev = {"name": name, "cat": cat, "ph": "X", "pid": tracer.pid,
                    "tid": threading.get_ident(), "args": args}
        self._t0 = 0.0

    def set(self, **args) -> "_Span":
        """Attach args discovered mid-span (e.g. a result count)."""
        self._ev["args"].update(args)
        return self

    def __enter__(self) -> "_Span":
        self._t0 = _now_us()
        return self

    def __exit__(self, *exc) -> None:
        ev = self._ev
        ev["ts"] = self._t0
        ev["dur"] = _now_us() - self._t0
        self._tracer._emit(ev)


class _NoopSpan:
    """Shared do-nothing span: what `span()` hands out when tracing is
    disabled. Stateless, hence safe to share across threads/reentries."""
    __slots__ = ()

    def set(self, **args) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NOOP = _NoopSpan()


class Tracer:
    """Buffered, thread-safe span collector writing Chrome-trace JSONL.

    `path=None` keeps events in memory only (tests, ad-hoc analysis —
    read them back with `events()`); with a path, `flush()`/`close()`
    append the buffered events one JSON object per line. Timestamps are
    microseconds from `perf_counter_ns` (monotonic, sub-us resolution);
    pid/tid are the real process/thread ids so multi-thread traces lay
    out one Perfetto track per thread.
    """

    def __init__(self, path: Optional[str] = None,
                 meta: Optional[Dict[str, Any]] = None):
        self.path = path
        self.pid = os.getpid()
        self.meta = dict(meta or {})
        self._lock = threading.Lock()
        self._buf: List[dict] = []
        self._all: List[dict] = []
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            # truncate + header: process metadata rides as an "M" event
            with open(path, "w") as f:
                f.write(json.dumps(self._meta_event()) + "\n")

    def _meta_event(self) -> dict:
        return {"name": "process_name", "cat": "__metadata", "ph": "M",
                "ts": 0, "pid": self.pid, "tid": 0,
                "args": dict(self.meta,
                             schema_version=TRACE_SCHEMA_VERSION)}

    # -- emission -----------------------------------------------------------
    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._buf.append(ev)
            self._all.append(ev)

    def span(self, name: str, cat: str = "host", **args) -> _Span:
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "host", **args) -> None:
        self._emit({"name": name, "cat": cat, "ph": "i", "ts": _now_us(),
                    "pid": self.pid, "tid": threading.get_ident(),
                    "s": "t", "args": args})

    def for_replica(self, r: int) -> "_ReplicaView":
        """A pid-view of this tracer for mesh replica `r`: events emitted
        through it carry a pid distinct from the host process (and from
        every other replica), so each replica lays out as its own
        Perfetto process track and `obs.report`'s per-pid mid-epoch-sync
        gate judges each replica's timeline separately. The first use of
        a replica emits its "M" process_name header."""
        views = self.__dict__.setdefault("_replica_views", {})
        view = views.get(r)
        if view is None:
            view = views[r] = _ReplicaView(self, r)
        return view

    # -- inspection / persistence -------------------------------------------
    def events(self) -> List[dict]:
        """All events emitted so far (including already-flushed ones),
        metadata header excluded."""
        with self._lock:
            return list(self._all)

    def flush(self) -> None:
        with self._lock:
            buf, self._buf = self._buf, []
        if self.path and buf:
            with open(self.path, "a") as f:
                for ev in buf:
                    f.write(json.dumps(ev) + "\n")

    def close(self) -> None:
        self.flush()


class _ReplicaView:
    """Per-replica pid facade over a `Tracer` (see `Tracer.for_replica`).

    Spans are emitted with EXPLICIT (ts, dur): replica timelines are
    reconstructed after the fact from per-step host dispatch timestamps
    plus the sharded step's per-replica aux outputs
    (`dist.gnn.ReplicaTraceEmitter`), never timed live — an SPMD step is
    one dispatch for all replicas, so live per-replica wall timing does
    not exist. Emission itself never syncs the device."""
    __slots__ = ("_tracer", "replica", "pid")

    def __init__(self, tracer: Tracer, r: int):
        self._tracer = tracer
        self.replica = r
        # distinct from the host pid and from every other replica view
        self.pid = tracer.pid * 1000 + r + 1
        tracer._emit({"name": "process_name", "cat": "__metadata",
                      "ph": "M", "ts": 0, "pid": self.pid, "tid": 0,
                      "args": {"name": f"replica {r}", "replica": r}})

    def emit_span(self, name: str, cat: str, ts: float, dur: float,
                  **args) -> None:
        self._tracer._emit({"name": name, "cat": cat, "ph": "X",
                            "ts": ts, "dur": dur, "pid": self.pid,
                            "tid": 0, "args": dict(args,
                                                   replica=self.replica)})

    def instant(self, name: str, cat: str = "host", **args) -> None:
        self._tracer._emit({"name": name, "cat": cat, "ph": "i",
                            "ts": _now_us(), "pid": self.pid, "tid": 0,
                            "s": "t",
                            "args": dict(args, replica=self.replica)})


# ---------------------------------------------------------------------------
# global tracer: the stack's call sites go through these free functions
# ---------------------------------------------------------------------------
_TRACER: Optional[Tracer] = None


def install(tracer: Tracer) -> Tracer:
    """Make `tracer` the stack-wide tracer (visible from every thread)."""
    global _TRACER
    _TRACER = tracer
    return tracer


def uninstall() -> Optional[Tracer]:
    """Disable tracing; returns (and flushes) the previous tracer."""
    global _TRACER
    t, _TRACER = _TRACER, None
    if t is not None:
        t.flush()
    return t


def current() -> Optional[Tracer]:
    return _TRACER


class enabled:
    """`with trace.enabled("t.jsonl") as t:` — install for the block."""

    def __init__(self, path: Optional[str] = None, **meta):
        self.tracer = Tracer(path, meta=meta)

    def __enter__(self) -> Tracer:
        return install(self.tracer)

    def __exit__(self, *exc) -> None:
        if _TRACER is self.tracer:
            uninstall()
        else:                       # someone swapped tracers mid-block
            self.tracer.flush()


def span(name: str, cat: str = "host", **args):
    """A span on the installed tracer, or the shared no-op when tracing
    is disabled — the ONE line hot paths pay."""
    t = _TRACER
    if t is None:
        return NOOP
    return t.span(name, cat, **args)


def instant(name: str, cat: str = "host", **args) -> None:
    t = _TRACER
    if t is not None:
        t.instant(name, cat, **args)


# ---------------------------------------------------------------------------
# sync-free device step timing
# ---------------------------------------------------------------------------
class DeviceStepTimer:
    """Accumulate per-step dispatch timestamps; close the window ONLY at
    an existing boundary sync.

    `note(out)` is called once per train step right after dispatch: it
    records the host timestamp and keeps a reference to the step's
    un-synced output array (a scalar — holding it is free and keeps the
    dispatch chain alive for the boundary drain). NO sync happens here.

    `flush(site=...)` is called immediately AFTER the caller's own
    boundary `block_until_ready` (epoch flush, n-step drain, checkpoint)
    and emits one "device_steps" span covering first-dispatch -> drained,
    with `n` steps and the derived per-step mean in its args. The timer
    itself never calls a sync primitive — the boundary sync it rides is
    one the trainer already performs, so enabling tracing adds zero
    host<->device round-trips (the `no-host-sync-in-hot-path` contract).
    """

    def __init__(self):
        self._t0: Optional[float] = None
        self._n = 0
        self._last = None           # un-synced output of the latest step

    def note(self, out: Any = None) -> None:
        if _TRACER is None:
            return
        if self._t0 is None:
            self._t0 = _now_us()
        self._n += 1
        self._last = out

    def flush(self, site: str = "epoch") -> None:
        """Emit the accumulated window (call AFTER the boundary drain)."""
        t = _TRACER
        if t is None or self._t0 is None:
            self._t0, self._n, self._last = None, 0, None
            return
        end = _now_us()
        dur = end - self._t0
        n = self._n
        t._emit({"name": "device_steps", "cat": "device", "ph": "X",
                 "ts": self._t0, "dur": dur, "pid": t.pid,
                 "tid": threading.get_ident(),
                 "args": {"n": n, "site": site,
                          "per_step_us": dur / max(n, 1)}})
        self._t0, self._n, self._last = None, 0, None
