"""`python -m repro.obs` — analyze a span trace, optionally gate on it.

    python -m repro.obs trace.jsonl                  # print the report
    python -m repro.obs trace.jsonl --json r.json    # also serialize it
    python -m repro.obs trace.jsonl --chrome t.json  # Perfetto-openable
                                                     #  traceEvents file
    python -m repro.obs trace.jsonl --require-overlap \
                                    --forbid-mid-epoch-sync
                                                     # CI gate: exit 1 if
                                                     #  overlap <= 0 or
                                                     #  any sync fired
                                                     #  mid-epoch

The report (see `obs/report.py`) carries producer/consumer overlap
fraction, per-stage stall attribution, host-sync placement, and
per-epoch span rollups. Open the --chrome output at ui.perfetto.dev for
the interactive timeline.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.obs import report as rpt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-obs",
        description="trace analyzer: overlap, stalls, sync placement, "
                    "per-epoch rollups")
    ap.add_argument("trace", help="JSONL trace written by obs.trace.Tracer")
    ap.add_argument("--json", default=None, help="serialize the report")
    ap.add_argument("--chrome", default=None,
                    help="write a {'traceEvents': ...} file Perfetto opens")
    ap.add_argument("--require-overlap", action="store_true",
                    help="exit 1 unless producer/consumer overlap > 0")
    ap.add_argument("--forbid-mid-epoch-sync", action="store_true",
                    help="exit 1 if any host-sync span fired mid-epoch")
    args = ap.parse_args(argv)

    events = rpt.load_trace(args.trace)
    r = rpt.analyze(events)

    ov, st = r["overlap"], r["stalls"]
    print(f"trace: {r['n_events']} events, {r['n_threads']} threads, "
          f"{r['wall_s']:.3f}s wall")
    if r["conformance_problems"]:
        for p in r["conformance_problems"][:10]:
            print(f"  CONFORMANCE: {p}")
    print(f"overlap: producer busy {ov['producer_busy_s']:.3f}s, "
          f"consumer busy {ov['consumer_busy_s']:.3f}s, "
          f"overlap {ov['overlap_s']:.3f}s "
          f"(frac {ov['overlap_frac']:.3f})")
    for name, e in sorted(st.items()):
        print(f"stall: {name:18s} x{e['count']:<4d} {e['total_s']:.3f}s "
              f"({e['frac_of_wall']:.1%} of wall)")
    for name, e in sorted(r["sync_sites"].items()):
        print(f"sync:  {name:18s} x{e['count']:<4d} {e['total_s']:.3f}s")
    for ep in r["epochs"]:
        top = sorted(ep["spans"].items(), key=lambda kv: -kv[1]["total_s"])
        tops = " ".join(f"{n}={e['total_s']:.3f}s" for n, e in top[:4])
        print(f"epoch {ep['epoch']}: {ep['n_steps']} steps "
              f"{ep['dur_s']:.3f}s, mid-epoch syncs "
              f"{ep['mid_epoch_syncs']} | {tops}")
    print(f"mid-epoch syncs total: {r['mid_epoch_sync_count']}")

    if args.json:
        Path(args.json).write_text(json.dumps(r, indent=1) + "\n")
        print(f"report -> {args.json}")
    if args.chrome:
        rpt.to_chrome(events, args.chrome)
        print(f"perfetto -> {args.chrome} (open at ui.perfetto.dev)")

    ok = not r["conformance_problems"]
    if args.require_overlap and not ov["overlap_frac"] > 0:
        print("GATE FAIL: producer/consumer overlap is 0")
        ok = False
    if args.forbid_mid_epoch_sync and r["mid_epoch_sync_count"] > 0:
        print(f"GATE FAIL: {r['mid_epoch_sync_count']} mid-epoch sync(s)")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
