"""`repro.obs`: unified tracing, metrics, and profiling.

Three cooperating pieces (see each module's docstring):

  obs.trace    structured span tracer -> Chrome-trace/Perfetto JSONL,
               zero-cost no-op when disabled, sync-free device step
               timing (`DeviceStepTimer`)
  obs.metrics  `MetricsHub` counter/gauge/histogram registry that
               absorbs `HitRateMeter` / `ResilienceMeter` /
               `StragglerMonitor`, with per-epoch snapshots and a
               versioned export schema (+ the shared `run_metadata`
               header every BENCH_*.json carries)
  obs.report   trace analyzer: producer/consumer overlap fraction,
               stall attribution by stage, host-sync placement gate,
               per-epoch rollups — also `python -m repro.obs`
"""
from repro.obs.metrics import (OBS_SCHEMA_VERSION, Counter, Gauge,
                               Histogram, MetricsHub, run_metadata)
from repro.obs.trace import (TRACE_SCHEMA_VERSION, DeviceStepTimer, Tracer,
                             current, enabled, install, instant, span,
                             uninstall)
from repro.obs.report import analyze, load_trace, to_chrome

__all__ = [
    "OBS_SCHEMA_VERSION", "TRACE_SCHEMA_VERSION",
    "Counter", "Gauge", "Histogram", "MetricsHub", "run_metadata",
    "DeviceStepTimer", "Tracer", "current", "enabled", "install",
    "instant", "span", "uninstall",
    "analyze", "load_trace", "to_chrome",
]
