"""Trace analysis: overlap, stall attribution, per-epoch rollups.

Consumes the JSONL span traces `obs.trace.Tracer` writes and computes
the numbers the paper's timing story needs a timeline for:

  producer/consumer overlap fraction
      wall-clock time the async producer thread spent building batches
      WHILE a consumer train step was in flight, as a fraction of total
      producer busy time. The whole point of `repro.pipeline`'s async
      prefetcher is that this is > 0 (batch construction hides behind
      device compute); CI gates it on a traced smoke run.

  stall attribution by stage
      total blocked time per wait site ("queue_get_wait" = consumer
      starved, "queue_put_wait" = producer backpressured — the healthy
      direction), as fractions of trace wall time.

  host-sync placement
      every host<->device sync the trainer performs is traced as a
      cat="sync" span. A sync is *mid-epoch* when it starts before the
      final train step of its enclosing epoch span — i.e. anywhere but
      the epoch/checkpoint boundary where the deterministic-execution
      contract allows it. CI gates `mid_epoch_count == 0` on the traced
      async run, turning the `no-host-sync-in-hot-path` lint's static
      claim into a measured runtime one.

  per-epoch span rollups
      per epoch: step count plus {span name -> count, total time},
      the coarse profile that shows where an epoch's wall time went.

All computations are pure functions over the event list, unit-tested on
synthetic span sets (tests/test_obs.py) so the analyzer's arithmetic is
pinned independently of the tracer.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs.trace import EVENT_KEYS, TRACE_SCHEMA_VERSION

Interval = Tuple[float, float]


# ---------------------------------------------------------------------------
# loading + schema conformance
# ---------------------------------------------------------------------------
def load_trace(path: str, include_meta: bool = False) -> List[dict]:
    """Parse a JSONL trace. Raises ValueError on an unparsable line —
    a torn trace should fail loudly, not analyze half a run."""
    events = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: bad trace line: {e}") \
                    from e
            if ev.get("ph") == "M" and not include_meta:
                continue
            events.append(ev)
    return events


def validate_events(events: Iterable[dict]) -> List[str]:
    """Chrome-trace conformance problems ([] = clean): every event has
    name/cat/ph/ts/pid/tid, complete events carry a non-negative dur,
    args (when present) is a dict."""
    problems = []
    for i, ev in enumerate(events):
        for k in EVENT_KEYS:
            if k not in ev:
                problems.append(f"event {i}: missing {k!r}: {ev}")
        if ev.get("ph") == "X":
            if "dur" not in ev:
                problems.append(f"event {i}: 'X' event without dur: {ev}")
            elif ev["dur"] < 0:
                problems.append(f"event {i}: negative dur: {ev}")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"event {i}: args not a dict: {ev}")
    return problems


def to_chrome(events: List[dict], path: str) -> str:
    """Write the `{"traceEvents": [...]}` wrapper ui.perfetto.dev and
    chrome://tracing open directly."""
    with open(path, "w") as f:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms"}, f)
    return path


# ---------------------------------------------------------------------------
# interval arithmetic (all in microseconds, as traced)
# ---------------------------------------------------------------------------
def _spans(events: Iterable[dict], cat: Optional[str] = None,
           name: Optional[str] = None) -> List[dict]:
    return [ev for ev in events if ev.get("ph") == "X"
            and (cat is None or ev.get("cat") == cat)
            and (name is None or ev.get("name") == name)]


def merge_intervals(ivals: Iterable[Interval]) -> List[Interval]:
    """Union of possibly-overlapping intervals as a sorted disjoint list."""
    out: List[Interval] = []
    for lo, hi in sorted(ivals):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def intersect_total(a: List[Interval], b: List[Interval]) -> float:
    """Total length of the intersection of two disjoint sorted lists."""
    total, i, j = 0.0, 0, 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return total


def _busy(events: Iterable[dict], cat: str) -> List[Interval]:
    return merge_intervals(
        [(ev["ts"], ev["ts"] + ev["dur"]) for ev in _spans(events, cat)])


def overlap_fraction(events: List[dict]) -> Dict:
    """Producer/consumer overlap: intersection of merged producer-thread
    build intervals (cat="producer") with merged consumer step intervals
    (cat="step"), normalized by producer busy time. A sync pipeline has
    no producer spans at all -> 0.0 by construction."""
    prod = _busy(events, "producer")
    cons = merge_intervals(_busy(events, "step") + _busy(events, "device"))
    steps = _busy(events, "step")
    prod_total = sum(hi - lo for lo, hi in prod)
    step_total = sum(hi - lo for lo, hi in steps)
    ov = intersect_total(prod, steps)
    return {"producer_busy_s": prod_total / 1e6,
            "consumer_busy_s": step_total / 1e6,
            "overlap_s": ov / 1e6,
            "overlap_frac": ov / prod_total if prod_total > 0 else 0.0,
            "overlap_frac_device": (intersect_total(prod, cons)
                                    / prod_total if prod_total > 0
                                    else 0.0)}


def stall_attribution(events: List[dict]) -> Dict:
    """Blocked time per wait site (cat="wait"), with fractions of trace
    wall time — "where did the pipeline wait, and on what"."""
    wall = _wall_us(events)
    out: Dict[str, Dict] = {}
    for ev in _spans(events, "wait"):
        e = out.setdefault(ev["name"], {"count": 0, "total_s": 0.0})
        e["count"] += 1
        e["total_s"] += ev["dur"] / 1e6
    for e in out.values():
        e["frac_of_wall"] = (e["total_s"] * 1e6 / wall) if wall else 0.0
    return out


def _wall_us(events: List[dict]) -> float:
    xs = [ev for ev in events if "ts" in ev and ev.get("ph") != "M"]
    if not xs:
        return 0.0
    lo = min(ev["ts"] for ev in xs)
    hi = max(ev["ts"] + ev.get("dur", 0.0) for ev in xs)
    return hi - lo


# ---------------------------------------------------------------------------
# epoch rollups + mid-epoch sync gate
# ---------------------------------------------------------------------------
def epoch_rollups(events: List[dict]) -> List[Dict]:
    """Per epoch envelope span (name="epoch", cat="loop"): step count,
    {span name -> count/total_s} for every span starting inside it, and
    the mid-epoch sync verdict.

    A cat="sync" span is MID-EPOCH when it starts before the start of
    the epoch's last train step: the only sanctioned sync placement is
    the epoch/checkpoint boundary, which by construction begins with (or
    nests inside) the final step of the epoch. An epoch with no steps
    (resume landed exactly on a boundary) cannot have mid-epoch syncs.

    Envelopes are judged PER PID: a multi-replica trace (one pid per
    replica, `trace.Tracer.for_replica`) carries one "epoch" envelope
    per replica, and each is rolled up against its own pid's events
    only — rank 0 being clean never masks a mid-epoch sync on rank 1,
    and another replica's step cannot launder a sync as boundary-placed
    (the gate ISSUE 10's per-replica fixtures pin)."""
    out = []
    for ep in sorted(_spans(events, "loop", "epoch"),
                     key=lambda ev: ev["ts"]):
        lo, hi = ep["ts"], ep["ts"] + ep["dur"]
        inside = [ev for ev in _spans(events)
                  if lo <= ev["ts"] <= hi and ev is not ep
                  and ev.get("pid") == ep.get("pid")]
        steps = [ev for ev in inside if ev.get("cat") == "step"]
        # no steps at all (resume landed on a boundary): everything in
        # the envelope IS the boundary, so nothing can be mid-epoch
        last_step_start = max((ev["ts"] for ev in steps), default=lo)
        mid = [ev for ev in inside if ev.get("cat") == "sync"
               and ev["ts"] < last_step_start]
        rollup: Dict[str, Dict] = {}
        for ev in inside:
            e = rollup.setdefault(ev["name"],
                                  {"count": 0, "total_s": 0.0})
            e["count"] += 1
            e["total_s"] += ev["dur"] / 1e6
        out.append({"epoch": ep.get("args", {}).get("epoch"),
                    "pid": ep.get("pid"),
                    "start_s": lo / 1e6, "dur_s": ep["dur"] / 1e6,
                    "n_steps": len(steps),
                    "spans": rollup,
                    "mid_epoch_syncs": len(mid),
                    "mid_epoch_sync_names": sorted({ev["name"]
                                                    for ev in mid})})
    return out


def sync_sites(events: List[dict]) -> Dict:
    out: Dict[str, Dict] = {}
    for ev in _spans(events, "sync"):
        e = out.setdefault(ev["name"], {"count": 0, "total_s": 0.0})
        e["count"] += 1
        e["total_s"] += ev["dur"] / 1e6
    return out


def analyze(events: List[dict]) -> Dict:
    """The full report `python -m repro.obs` prints/serializes."""
    problems = validate_events(events)
    epochs = epoch_rollups(events)
    return {
        "schema": TRACE_SCHEMA_VERSION,
        "n_events": len(events),
        "n_threads": len({ev.get("tid") for ev in events}),
        "wall_s": _wall_us(events) / 1e6,
        "conformance_problems": problems,
        "overlap": overlap_fraction(events),
        "stalls": stall_attribution(events),
        "sync_sites": sync_sites(events),
        "epochs": epochs,
        "mid_epoch_sync_count": sum(e["mid_epoch_syncs"] for e in epochs),
        # per-pid gate: every replica's trace must be clean, not just
        # rank 0's — a nonzero entry names the offending pid directly
        "mid_epoch_sync_by_pid": _by_pid(epochs),
    }


def _by_pid(epochs: List[Dict]) -> Dict:
    out: Dict[str, int] = {}
    for e in epochs:
        k = str(e.get("pid"))
        out[k] = out.get(k, 0) + e["mid_epoch_syncs"]
    return out
