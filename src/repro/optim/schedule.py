"""LR schedules. `ReduceLROnPlateau` mirrors the paper's training
methodology (PyTorch defaults, patience=3)."""
from __future__ import annotations

import math


def cosine_warmup(base_lr: float, warmup: int, total: int):
    def lr(step: int) -> float:
        if step < warmup:
            return base_lr * (step + 1) / max(warmup, 1)
        t = (step - warmup) / max(total - warmup, 1)
        return base_lr * 0.5 * (1 + math.cos(math.pi * min(t, 1.0)))
    return lr


class ReduceLROnPlateau:
    """Host-side plateau scheduler (paper §5: factor=0.1, patience=3)."""

    def __init__(self, base_lr: float, factor: float = 0.1,
                 patience: int = 3, min_lr: float = 1e-6):
        self.lr = base_lr
        self.factor = factor
        self.patience = patience
        self.min_lr = min_lr
        self.best = math.inf
        self.bad = 0

    def step(self, metric: float) -> float:
        if metric < self.best - 1e-6:
            self.best = metric
            self.bad = 0
        else:
            self.bad += 1
            if self.bad > self.patience:
                self.lr = max(self.lr * self.factor, self.min_lr)
                self.bad = 0
        return self.lr


class EarlyStopping:
    """Stop when val loss hasn't improved for `patience` epochs (paper: 6)."""

    def __init__(self, patience: int = 6):
        self.patience = patience
        self.best = math.inf
        self.bad = 0
        self.best_epoch = -1

    def update(self, metric: float, epoch: int) -> bool:
        """Returns True if training should stop."""
        if metric < self.best - 1e-6:
            self.best = metric
            self.bad = 0
            self.best_epoch = epoch
            return False
        self.bad += 1
        return self.bad >= self.patience
