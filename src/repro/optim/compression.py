"""int8 gradient compression with error feedback (cross-pod all-reduce).

Per-leaf blockwise symmetric quantization: g ~ scale * int8. The residual
(g - dequant) is carried in an error-feedback buffer and added to the next
step's gradient, so compression error does not bias convergence (EF-SGD).
Intended for the slow cross-pod axis; intra-pod reductions stay full
precision.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant_leaf(g):
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_leaf(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def init_error_feedback(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads as would arrive post-all-reduce, new error
    buffers). Quantization is simulated end-to-end so tests measure exact
    round-trip error; on hardware the int8 payload is what crosses the pod
    link (4x reduction vs f32)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quant_leaf(g32)
        deq = _dequant_leaf(q, scale, g.shape)
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def compressed_bytes(grads: Any) -> int:
    """Payload model: int8 + one f32 scale per BLOCK."""
    tot = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        tot += n + 4 * ((n + BLOCK - 1) // BLOCK)
    return tot
