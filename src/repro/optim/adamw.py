"""AdamW on raw pytrees (no optax dependency). States are fp32 and shard
identically to params (ZeRO-equivalent given 2D-sharded params)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(grads, state, params, *, lr, b1=0.9, b2=0.999, eps=1e-8,
           weight_decay=0.0) -> Tuple[Any, Dict[str, Any]]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32)
        return (p - lr * step).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
