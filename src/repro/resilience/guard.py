"""Guard policy for the GNN train step (skip budget + rollback budget).

The detection itself lives inside the jitted train step
(`train.gnn_loop._make_steps`): loss and every grad leaf are checked for
finiteness on device, a non-finite step applies NO update (a `jnp.where`
select keeps the old params/optimizer state), and a device-resident
consecutive-skip counter rides through the step. None of that costs a
host sync. What this module configures is the HOST side: how often the
trainer syncs that one counter, how many consecutive skips it tolerates
before escalating, and how many rollback-to-checkpoint escalations it
will attempt before giving up (`train.monitor.StepFailure`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class GuardConfig:
    """Guarded-execution knobs for `GNNTrainer(guard=...)`.

    max_consecutive_skips  skip budget: more consecutive non-finite
                           steps than this escalates to a rollback
    check_every            sync the device skip counter every N steps
                           (1 = every step — exact but one scalar sync
                           per step; 0 = only at flush points: epoch
                           end, end of `train_steps`, and checkpoint
                           boundaries — sync-free steady state, but a
                           skip burst is detected up to a flush late)
    max_rollbacks          lifetime rollback budget before the trainer
                           raises `StepFailure` instead of retrying
    """
    max_consecutive_skips: int = 3
    check_every: int = 0
    max_rollbacks: int = 4

    def __post_init__(self):
        if self.max_consecutive_skips < 0 or self.check_every < 0 \
                or self.max_rollbacks < 0:
            raise ValueError(f"negative guard knob: {self}")


def as_guard(obj) -> Optional[GuardConfig]:
    """Normalize `GNNTrainer(guard=)`: None/False -> off, True -> the
    default `GuardConfig`, a `GuardConfig` passes through."""
    if obj is None or obj is False:
        return None
    if obj is True:
        return GuardConfig()
    if isinstance(obj, GuardConfig):
        return obj
    raise TypeError(f"guard must be None/bool/GuardConfig, got {obj!r}")
