"""Deterministic, seeded fault injection for the GNN training stack.

Chaos testing is only useful if every scenario REPLAYS: a fault that
fires at a nondeterministic point produces a nondeterministic recovery
path, and "recovered" stops being checkable bit-for-bit. This module
keeps the whole story deterministic:

  * a `FaultPlan` arms named sites with `FaultSpec`s whose trigger points
    are *invocation indices* (the N-th time the site is reached), drawn
    either explicitly or from a seeded schedule (`FaultPlan.seeded`);
  * production code calls `fire(site)` at each injection point — a
    module-global check that is a single `is None` test when no plan is
    installed, so the hooks cost nothing in normal runs;
  * corruption payloads (which file to truncate, which byte to flip,
    which cache entry to scramble) come from `payload_rng(spec)`, a
    generator seeded by (plan seed, site, trigger) — the damage itself
    replays too.

The five wired sites (see `FAULT_SITES`):

  batch_build     `pipeline.builder.DeviceBatchBuilder.build` raises
                  `InjectedFault` (producer-thread build failure)
  producer_hang   `pipeline.prefetch.AsyncBatchStream`'s producer stops
                  heartbeating and producing (hung thread)
  step_nonfinite  the GNN train step's loss is poisoned to NaN (and so
                  are its grads) for the armed invocations
  ckpt_truncate   `train.checkpoint.save` corrupts the checkpoint it
                  just wrote (torn write / bit rot)
  cache_corrupt   `featcache.dynamic.refill` returns a state whose
                  residency invariants are violated

Every fire is recorded on `plan.events` so tests can assert the fault
actually happened (a chaos test whose fault never fired proves nothing).
Counters are lock-protected: `batch_build`/`producer_hang` fire from the
prefetch producer thread.
"""
from __future__ import annotations

import os
import threading
import zlib
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

FAULT_SITES = ("batch_build", "producer_hang", "step_nonfinite",
               "ckpt_truncate", "cache_corrupt")


class InjectedFault(RuntimeError):
    """The exception raised by raising fault sites (`batch_build`)."""

    def __init__(self, site: str, invocation: int):
        super().__init__(f"injected fault at site {site!r} "
                         f"(invocation {invocation})")
        self.site = site
        self.invocation = invocation


@dataclass(frozen=True)
class FaultSpec:
    """Arm `site` for invocations [start, start + count)."""
    site: str
    start: int
    count: int = 1

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"known: {FAULT_SITES}")
        if self.start < 0 or self.count < 1:
            raise ValueError(f"bad trigger window ({self.start}, "
                             f"{self.count})")

    def armed_at(self, invocation: int) -> bool:
        return self.start <= invocation < self.start + self.count


@dataclass
class FaultPlan:
    """A set of armed fault sites plus the runtime counters/events of one
    injected run. `fire` is how sites consult the plan; the same plan
    object replayed over the same deterministic call sequence fires at
    exactly the same points."""
    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0
    counters: Dict[str, int] = field(default_factory=dict)
    events: List[dict] = field(default_factory=list)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    @classmethod
    def seeded(cls, seed: int, windows: Dict[str, Tuple[int, int]],
               counts: Optional[Dict[str, int]] = None) -> "FaultPlan":
        """Draw one trigger per site from a seeded schedule: `windows`
        maps site -> inclusive (lo, hi) invocation range, `counts` maps
        site -> how many consecutive invocations stay armed (default 1).
        Sites are visited in `FAULT_SITES` order so the draws are a pure
        function of (seed, windows)."""
        rng = np.random.default_rng((seed, 0))  # salt 0: legacy slot
        counts = counts or {}
        specs = []
        for site in FAULT_SITES:
            if site not in windows:
                continue
            lo, hi = windows[site]
            specs.append(FaultSpec(site, int(rng.integers(lo, hi + 1)),
                                   counts.get(site, 1)))
        return cls(specs=tuple(specs), seed=seed)

    def fire(self, site: str, **ctx) -> Optional[FaultSpec]:
        """Count one invocation of `site`; return the armed spec if this
        invocation is inside its trigger window (else None)."""
        with self._lock:
            i = self.counters.get(site, 0)
            self.counters[site] = i + 1
            for spec in self.specs:
                if spec.site == site and spec.armed_at(i):
                    self.events.append({"site": site, "invocation": i,
                                        **ctx})
                    return spec
        return None

    def fired(self, site: Optional[str] = None) -> List[dict]:
        return [e for e in self.events
                if site is None or e["site"] == site]

    def payload_rng(self, spec: FaultSpec) -> np.random.Generator:
        """Deterministic generator for the fault's corruption payload."""
        return np.random.default_rng(
            (self.seed, zlib.crc32(spec.site.encode()), spec.start))


# ---------------------------------------------------------------------------
# the installed plan (module global, one per process)
# ---------------------------------------------------------------------------
_PLAN: Optional[FaultPlan] = None


def install(plan: Optional[FaultPlan]) -> None:
    global _PLAN
    _PLAN = plan


def active() -> Optional[FaultPlan]:
    return _PLAN


@contextmanager
def inject(plan: FaultPlan):
    """Install `plan` for the duration of the block (not reentrant —
    chaos scenarios run one plan at a time)."""
    prev = _PLAN
    install(plan)
    try:
        yield plan
    finally:
        install(prev)


def fire(site: str, **ctx) -> Optional[FaultSpec]:
    """The hook production code calls at an injection point: a no-op
    (single global read) unless a plan is installed AND armed here."""
    plan = _PLAN
    if plan is None:
        return None
    return plan.fire(site, **ctx)


def maybe_raise(site: str, **ctx) -> None:
    """`fire`, then raise `InjectedFault` if armed (raising sites)."""
    plan = _PLAN
    if plan is None:
        return
    spec = plan.fire(site, **ctx)
    if spec is not None:
        raise InjectedFault(site, plan.counters[site] - 1)


# ---------------------------------------------------------------------------
# corruption payloads
# ---------------------------------------------------------------------------
def corrupt_file(path: str, rng: np.random.Generator,
                 mode: Optional[str] = None) -> dict:
    """Deterministically damage one file: `truncate` (torn write — keep a
    prefix) or `flip` (bit rot — invert one byte). Returns what was done."""
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if mode is None:
        mode = "truncate" if rng.integers(2) else "flip"
    if mode == "truncate" or not data:
        keep = int(rng.integers(0, max(len(data) // 2, 1)))
        data = data[:keep]
    else:
        i = int(rng.integers(len(data)))
        data[i] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(data))
    return {"file": os.path.basename(path), "mode": mode,
            "size": len(data)}


def corrupt_checkpoint(step_dir: str, rng: np.random.Generator,
                       mode: Optional[str] = None,
                       target: Optional[str] = None) -> dict:
    """Damage one file of a `step_*` checkpoint directory (manifest or a
    random leaf) — the `ckpt_truncate` payload, also used directly by the
    corruption property tests."""
    files = sorted(f for f in os.listdir(step_dir)
                   if f == "manifest.json" or f.startswith("leaf_"))
    if target is None:
        target = files[int(rng.integers(len(files)))]
    return corrupt_file(os.path.join(step_dir, target), rng, mode)
