"""`repro.resilience` — deterministic fault injection + guarded execution.

A long training run dies in boring ways: a corrupt checkpoint, a hung
producer thread, a NaN loss, a scribbled-over cache. This subsystem makes
each of those a *replayable* scenario and gives every layer of the GNN
stack a bounded recovery path:

  faults    seeded `FaultPlan` arming five named sites (`batch_build`,
            `producer_hang`, `step_nonfinite`, `ckpt_truncate`,
            `cache_corrupt`) wired into `pipeline.builder`,
            `pipeline.prefetch`, `train.checkpoint`, `featcache.dynamic`
            and the GNN train step — every chaos run replays exactly
  guard     `GuardConfig` for the guarded train step: in-jit non-finite
            detection + skip (no host sync), a consecutive-skip budget,
            rollback-to-checkpoint escalation, all metered by
            `train.monitor.ResilienceMeter`
  soak      the chaos harness: inject one fault from each class into a
            comm_rand x LABOR + dynamic-cache run and assert the
            recovered loss trajectory is BIT-IDENTICAL to the fault-free
            run (`benchmarks/chaos_soak.py` gates this in CI)

Recovery guarantees (all bit-exact because batches, dropout keys and
cache state are pure functions of the checkpointed cursor):
`AsyncBatchStream` restarts a dead/hung producer from the current cursor
(exponential backoff, bounded budget); `restore_latest` falls back past
corrupt checkpoints to the newest valid one; a non-finite step applies
no update and escalates to rollback after the skip budget; a cache
failing its residency integrity check is dropped for the uncached gather
(cache rows are bit-copies, so the loss trajectory is unaffected).

`repro.resilience.soak` is imported lazily (it pulls in the trainer).
"""
from repro.resilience.faults import (FAULT_SITES, FaultPlan,  # noqa: F401
                                     FaultSpec, InjectedFault, active,
                                     corrupt_checkpoint, corrupt_file,
                                     fire, inject, install, maybe_raise)
from repro.resilience.guard import GuardConfig, as_guard      # noqa: F401

__all__ = [
    "FAULT_SITES", "FaultPlan", "FaultSpec", "GuardConfig",
    "InjectedFault", "active", "as_guard", "corrupt_checkpoint",
    "corrupt_file", "fire", "inject", "install", "maybe_raise",
]
