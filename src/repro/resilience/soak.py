"""Chaos soak: every fault class, one guarded run, bit-exact recovery.

The headline robustness claim of `repro.resilience`: inject one fault of
every class (`faults.FAULT_SITES`) into a comm_rand x LABOR +
dynamic-cache training run and the run must (a) recover automatically
through the matching mechanism and (b) land on a final loss trajectory
AND parameter digest bit-identical to a fault-free run. That bar is only
reachable because the whole stack is deterministic in the checkpointed
`Cursor` (PR 6): batches, dropout keys and cache state replay exactly,
so every recovery path — producer restart, skip + rollback, checkpoint
fallback, cache degradation — converges back onto the reference
trajectory instead of merely "continuing".

Per-scenario recovery mechanism asserted (`EXPECT_METER`):

  batch_build     producer thread dies mid-build -> watchdog restart
  producer_hang   producer stops heartbeating    -> watchdog restart
  step_nonfinite  NaN loss burst past the skip budget -> rollback+replay
  ckpt_truncate   newest checkpoint corrupted    -> restore falls back
  cache_corrupt   residency invariants broken    -> degrade to uncached

`run_scenario` returns a `SoakResult`; `run_all` is what
`benchmarks/chaos_soak.py` drives and CI asserts on. Loss comparison is
EXACT float equality (`==`), never allclose: any poisoned step the
recovery failed to replay leaves a NaN behind, and NaN != NaN fails the
bit-match — silent partial recovery cannot pass.
"""
from __future__ import annotations

import hashlib
import tempfile
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.batching.policy import CommRandPolicy
from repro.configs.base import GNNConfig, TrainConfig
from repro.resilience import faults
from repro.resilience.guard import GuardConfig
from repro.train.gnn_loop import GNNTrainer

BATCH = 128
FANOUTS = (5, 5)
CAPS = (512, 1024)
SEED = 3                # trainer/stream seed (matches the PR 6 tests)
CKPT_EVERY = 4
N_STEPS = 20
GUARD = GuardConfig(max_consecutive_skips=2, check_every=1,
                    max_rollbacks=4)
STALL_S = 1.0           # post-`prime()` watchdog timeout (hang recovery)

# seeded trigger windows per site: inclusive (lo, hi) INVOCATION range
# the fault's start is drawn from (`FaultPlan.seeded`)
WINDOWS: Dict[str, Tuple[int, int]] = {
    "batch_build": (6, 14),      # a mid-run producer build
    "producer_hang": (6, 14),    # a mid-run producer loop turn
    "step_nonfinite": (6, 12),   # a burst starting after the 1st ckpt
    "ckpt_truncate": (1, 1),     # the 2nd save (step 8) gets damaged
    "cache_corrupt": (0, 1),     # an early epoch-boundary refill
}

# the ResilienceMeter counter each fault class must have engaged
EXPECT_METER = {
    "batch_build": "producer_restarts",
    "producer_hang": "producer_restarts",
    "step_nonfinite": "rollbacks",
    "ckpt_truncate": "ckpt_fallbacks",
    "cache_corrupt": "cache_degradations",
}


class CommRandLaborPolicy(CommRandPolicy):
    """comm_rand root ordering x LABOR shared-randomness sampler — the
    paper's structure-aware cross product, trained here under chaos."""

    def sampler_spec(self):
        return ("labor", {})


def make_trainer(graph, *, pipeline: str = "async", ckpt_dir=None,
                 ckpt_every: int = CKPT_EVERY, guard=GUARD,
                 seed: int = SEED) -> GNNTrainer:
    """The soak's fixed configuration: 2-layer SAGE, comm_rand x LABOR,
    dynamic degree_hot cache, guarded, async pipeline by default."""
    cfg = GNNConfig("sage-soak", "sage", 2, 16, graph.feat_dim,
                    graph.num_classes, fanout=FANOUTS)
    tcfg = TrainConfig(batch_size=BATCH, max_epochs=4)
    return GNNTrainer(graph, cfg, tcfg,
                      CommRandLaborPolicy("comm_rand", 0.125, 1.0),
                      caps=CAPS, eval_caps=CAPS, seed=seed,
                      cache="dynamic:degree_hot", pipeline=pipeline,
                      ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                      guard=guard)


def params_digest(params) -> str:
    """sha1 over the raw bytes of every param leaf — digest equality is
    bit equality of the final weights."""
    h = hashlib.sha1()
    for leaf in jax.tree.leaves(params):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


def run_steps_tracked(tr: GNNTrainer, n: int) -> Dict[int, float]:
    """Advance `tr` to global step `n`, recording the FINAL loss each
    step settled on: a rollback rewinds `global_step`, and the replayed
    steps overwrite their poisoned entries — so the returned dict is the
    trajectory the run actually converged to, comparable `==` against a
    fault-free run."""
    losses: Dict[int, float] = {}
    iters, budget = 0, 8 * n + 16
    while tr.global_step < n:
        prev = tr.global_step
        (loss,) = tr.train_steps(1)
        if tr.global_step == prev + 1:
            losses[tr.global_step] = loss
        # a rollback rewound the step counter: record nothing, the
        # replay re-enters this loop and overwrites
        iters += 1
        if iters > budget:
            raise RuntimeError(
                f"soak stuck: step {tr.global_step}/{n} after "
                f"{iters} iterations")
    return losses


@dataclass
class SoakResult:
    """One scenario's verdict (JSON-able via `summary()`)."""
    scenario: str
    n_steps: int
    fired: int                  # armed fires of the scenario's site
    bitmatch: bool              # loss trajectory == fault-free reference
    digest_match: bool          # final params sha1 == reference
    recovered: bool             # expected recovery mechanism engaged
    meter: Dict[str, int]       # summed ResilienceMeter counts
    events: List[dict]          # the plan's fire log

    @property
    def ok(self) -> bool:
        """Fault actually fired, expected recovery ran, and the run is
        bit-identical to fault-free — all three, or the scenario fails."""
        return bool(self.fired > 0 and self.recovered and self.bitmatch
                    and self.digest_match)

    def summary(self) -> dict:
        return {"scenario": self.scenario, "ok": self.ok,
                "n_steps": self.n_steps, "fired": self.fired,
                "bitmatch": self.bitmatch,
                "digest_match": self.digest_match,
                "recovered": self.recovered, "meter": dict(self.meter)}


def run_reference(graph, n: int = N_STEPS):
    """The fault-free reference: SYNC pipeline (so the comparison also
    cross-checks async==sync), same guard (with `poison=1.0` the guard
    is a bitwise no-op), no checkpointing."""
    tr = make_trainer(graph, pipeline="sync", ckpt_dir=None, ckpt_every=0)
    losses = run_steps_tracked(tr, n)
    return losses, params_digest(tr.params)


def run_scenario(graph, site: str, *, n: int = N_STEPS, seed: int = 11,
                 ref=None) -> SoakResult:
    """Inject one seeded fault of class `site` into a guarded async run
    and score the recovery against the fault-free reference."""
    if site not in faults.FAULT_SITES:
        raise ValueError(f"unknown scenario {site!r}; "
                         f"known: {faults.FAULT_SITES}")
    if ref is None:
        ref = run_reference(graph, n)
    ref_losses, ref_digest = ref
    # step_nonfinite must BURST past the skip budget or it never
    # escalates (and the skipped batches would never be replayed)
    counts = {site: GUARD.max_consecutive_skips + 1} \
        if site == "step_nonfinite" else None
    plan = faults.FaultPlan.seeded(seed, {site: WINDOWS[site]}, counts)
    meters = []

    with tempfile.TemporaryDirectory() as d:
        tr = make_trainer(graph, pipeline="async", ckpt_dir=d)
        tr.stream.prime()               # compile BEFORE arming the watchdog
        tr.stream.stall_timeout_s = STALL_S
        try:
            with faults.inject(plan):
                if site == "ckpt_truncate":
                    # drive past the corrupted save (invocation 1 = the
                    # step-2*CKPT_EVERY save), then simulate a process
                    # crash WHILE it is still the newest checkpoint: the
                    # next trainer must resume by falling back past it
                    crash = 2 * CKPT_EVERY + 2
                    if n <= crash:
                        raise ValueError(
                            f"ckpt_truncate scenario needs n > {crash}")
                    losses = run_steps_tracked(tr, crash)
                    meters.append(tr.guard_meter)
                    tr.stream.close()
                    tr = make_trainer(graph, pipeline="async", ckpt_dir=d)
                    tr.stream.prime()
                    tr.stream.stall_timeout_s = STALL_S
                    losses.update(run_steps_tracked(tr, n))
                else:
                    losses = run_steps_tracked(tr, n)
            meters.append(tr.guard_meter)
            digest = params_digest(tr.params)
        finally:
            tr.stream.close()

    meter = {k: sum(m.counts()[k] for m in meters)
             for k in meters[0]._KINDS}
    return SoakResult(
        scenario=site, n_steps=n, fired=len(plan.fired(site)),
        bitmatch=(losses == ref_losses),
        digest_match=(digest == ref_digest),
        recovered=meter[EXPECT_METER[site]] > 0,
        meter=meter, events=list(plan.events))


def run_all(graph, *, n: int = N_STEPS, sites=faults.FAULT_SITES,
            seed: int = 11, verbose: bool = False) -> List[SoakResult]:
    """One scenario per fault class against a shared reference run."""
    ref = run_reference(graph, n)
    out = []
    for site in sites:
        res = run_scenario(graph, site, n=n, seed=seed, ref=ref)
        if verbose:
            print(f"  {site:15s} ok={res.ok} fired={res.fired} "
                  f"bitmatch={res.bitmatch} digest={res.digest_match} "
                  f"meter={ {k: v for k, v in res.meter.items() if v} }")
        out.append(res)
    return out
