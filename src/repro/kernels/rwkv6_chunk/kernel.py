"""Pallas TPU kernel: chunked WKV6 recurrence (RWKV6 token mixing).

Grid (B, H, T/C) with the chunk axis innermost and sequential, carrying the
(N, N) per-head state in VMEM scratch across chunk steps. Within a chunk the
recurrence is evaluated in dense matmul form (MXU-friendly) — the same math
as `repro.models.lm.rwkv6.wkv6_chunked` (see there for the stability
argument: |logw| * C < 88 keeps exp() inside fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 16


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)            # (C, N)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = w_ref[0, :, 0, :].astype(jnp.float32)           # log-decay < 0
    u = u_ref[0, :].astype(jnp.float32)                  # (N,)

    cum = jnp.cumsum(lw, axis=0)
    cum_prev = cum - lw
    q_dec = r * jnp.exp(cum_prev)
    k_dec = k * jnp.exp(-cum)
    scores = jax.lax.dot_general(q_dec, k_dec, (((1,), (1,)), ((), ())))
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(ii > jj, scores, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=1)           # (C,)
    scores = scores + jnp.where(ii == jj, diag[:, None], 0.0)

    out = jax.lax.dot(scores, v)                         # (C, N)
    out = out + jax.lax.dot(q_dec, s_ref[...])
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)

    last = cum[chunk - 1]                                # (N,)
    k_rem = k * jnp.exp(last[None, :] - cum)
    s_ref[...] = jnp.exp(last)[:, None] * s_ref[...] + \
        jax.lax.dot_general(k_rem, v, (((0,), (0,)), ((), ())))


def wkv6_pallas(r, k, v, logw, u, *, chunk=CHUNK, interpret=False):
    """r/k/v/logw: (B, T, H, N); u: (H, N). Returns out (B, T, H, N) f32.

    State starts at zero (training segments); T % chunk == 0.
    """
    B, T, H, N = r.shape
    assert T % chunk == 0
    grid = (B, H, T // chunk)
    spec = pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0))
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, N), lambda b, h, c: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, H, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
