"""jit'd wrapper for the WKV6 chunk kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6_chunk.kernel import CHUNK, wkv6_pallas


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6_op(r, k, v, logw, u, chunk: int = CHUNK):
    interpret = jax.default_backend() != "tpu"
    return wkv6_pallas(r, k, v, logw, u, chunk=chunk, interpret=interpret)
