"""Oracle: exact per-timestep WKV6 scan."""
import jax.numpy as jnp

from repro.models.lm.rwkv6 import wkv6_scan


def wkv6_ref(r, k, v, logw, u):
    B, T, H, N = r.shape
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    out, _ = wkv6_scan(r, k, v, logw, u, s0)
    return out.astype(jnp.float32)
