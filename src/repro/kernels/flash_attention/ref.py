"""Oracle: re-export the naive O(S^2) attention."""
from repro.models.lm.attention import attention_ref  # noqa: F401
