"""Pallas TPU flash attention (forward) with causal + sliding-window masks.

Grid (B, H, Sq/bq, Skv/bk); the KV axis is innermost and sequential, carrying
the online-softmax state (m, l, acc) in VMEM scratch. GQA is handled in the
K/V index_maps (head h reads kv-head h // group). MXU-aligned 128-tiles.

The training path uses the custom-VJP jnp twin
(`repro.models.lm.attention.flash_attention`) — identical math, validated
against each other and `attention_ref` in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            scale, bq, bk, causal, window, is_global, q_offset):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, :, 0, :].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kv_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok = ok & (kv_pos <= q_pos)
    if not is_global:
        ok = ok & ((q_pos - kv_pos) < window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot(p, v)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_s[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=1 << 30,
                           is_global=True, q_offset=0, bq=128, bk=128,
                           interpret=False):
    """q: (B, Sq, H, D); k/v: (B, Skv, KH, D) with H % KH == 0."""
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    grid = (B, H, Sq // bq, Skv // bk)
    kernel = functools.partial(
        _kernel, scale=1.0 / np.sqrt(D), bq=bq, bk=bk, causal=causal,
        window=window, is_global=is_global, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, g=G: (b, ki, h // g, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, qi, ki, g=G: (b, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
