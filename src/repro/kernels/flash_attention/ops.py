"""jit'd wrapper: Pallas kernel on TPU, interpret mode elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "window", "is_global",
                                             "q_offset", "bq", "bk"))
def flash_attention_op(q, k, v, *, causal=True, window=1 << 30,
                       is_global=True, q_offset=0, bq=128, bk=128):
    interpret = jax.default_backend() != "tpu"
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, is_global=is_global,
        q_offset=q_offset, bq=bq, bk=bk, interpret=interpret)
