"""Pallas TPU kernels for the compute hot spots, each shipped as a
`kernel.py` (the Pallas implementation) + `ops.py` (jit-able public wrapper
with backend/interpret dispatch and, where training needs it, a custom VJP)
+ `ref.py` (pure-jnp oracle the tests compare against).

- `gather_agg`    — fused gather + per-edge-weighted reduce, the GNN
                    aggregation hot loop (forward AND backward avoid the
                    (n_dst, fanout, F) intermediate). See README §kernels.
- `gather_cached` — two-level (cache-or-global) feature row gather for
                    the device-resident cache (`repro.featcache`), with
                    device-side hit/miss counters; its backward reuses
                    `gather_agg`'s scatter-add.
- `gather_mean`   — DEPRECATED shim over `gather_agg` (masked mean).
- `flash_attention`, `moe_gmm`, `rwkv6_chunk` — LM-side kernels.
"""
