"""Pallas TPU kernel: two-level (cache-or-global) feature row gather.

Layer-0 of the GNN reads one (F,)-row per unique input node. With a
device-resident cache (`repro.featcache.CachePlan`) each row lives either
in the compact (C, F) cache array or in the global (N, F) feature matrix:

    out[k] = cache[pos[ids[k]]]   if pos[ids[k]] >= 0   (hit)
           = feats[ids[k]]        otherwise             (miss)

Grid: one step per id, with ids PRE-PARTITIONED by hit flag outside the
kernel (hits first — the same pre-sort trick `gather_agg`'s backward uses
for consecutive accumulation). Both tables arrive through BlockSpec index
maps driven by scalar-prefetched row arrays; the UNSELECTED table's row
index is pinned to 0, and because the partition makes that pin contiguous
(the whole miss tail pins the cache stream, the whole hit head pins the
feats stream), the pipeline skips the re-fetch of an unchanged block — so
HBM traffic is one row per id (+2 pinned rows), not two. That is the
cache's bandwidth story: a hit never touches the global matrix.

Output rows land at the ORIGINAL id positions via a scalar-prefetched
inverse permutation; every output block is written exactly once.

Backward needs no new kernel: d_cache/d_feats are masked scatter-adds of
the cotangent rows, exactly `gather_agg_bwd_dx_pallas` with fanout 1 (see
`ops.py`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fwd_kernel(crow_ref, frow_ref, hit_ref, orow_ref, cache_ref, feats_ref,
                o_ref):
    del crow_ref, frow_ref, orow_ref    # consumed by the BlockSpec maps
    e = pl.program_id(0)
    o_ref[...] = jnp.where(hit_ref[e] > 0,
                           cache_ref[...].astype(jnp.float32),
                           feats_ref[...].astype(jnp.float32))


def gather_cached_fwd_pallas(cache, feats, crow, frow, hit, orow, *,
                             interpret: bool = False):
    """cache: (C, F); feats: (N, F); crow/frow: (M,) int32 row to stream
    from each table (0-pinned where the table is not selected); hit: (M,)
    int32 selector; orow: (M,) int32 output row (the inverse of the
    hit-partition permutation). Returns (M, F) float32. Callers partition
    ids so `hit` is non-increasing (see module docstring)."""
    M = crow.shape[0]
    F = feats.shape[1]
    return pl.pallas_call(
        _fwd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(M,),
            in_specs=[
                pl.BlockSpec((1, F), lambda e, cr, fr, h, orw: (cr[e], 0)),
                pl.BlockSpec((1, F), lambda e, cr, fr, h, orw: (fr[e], 0)),
            ],
            out_specs=pl.BlockSpec((1, F),
                                   lambda e, cr, fr, h, orw: (orw[e], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, F), jnp.float32),
        interpret=interpret,
    )(crow, frow, hit, orow, cache, feats)
