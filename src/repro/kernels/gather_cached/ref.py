"""Pure-jnp oracle for the two-level cached gather.

`gather_cached_ref` is also the production `cache_impl="jnp"` path: XLA
lowers the double gather + select well enough on CPU/GPU, but it always
reads BOTH candidate rows (cache and global) per id — the Pallas kernel's
hit-partitioned streaming is what makes a hit skip the global-matrix HBM
read on TPU.
"""
import jax.numpy as jnp


def gather_cached_ref(cache, feats, pos, ids):
    """out[k] = cache[pos[ids[k]]] if pos[ids[k]] >= 0 else feats[ids[k]].

    cache: (C, F) float32 (exact copies of admitted rows); feats: (N, F);
    pos: (N,) int32 position map (-1 = miss); ids: (M,) int global row
    ids, entries outside [0, N) are padding and served from a clipped
    global row (callers mask them). Returns (M, F) float32.
    """
    N = feats.shape[0]
    gid = jnp.clip(ids.astype(jnp.int32), 0, N - 1)
    sel = pos[gid]
    hit = (sel >= 0) & (ids >= 0) & (ids < N)
    return jnp.where(
        hit[:, None],
        cache[jnp.maximum(sel, 0)].astype(jnp.float32),
        feats[gid].astype(jnp.float32))
