"""Public two-level cached gather with a custom VJP + device hit counters.

`gather_cached(cache, feats, pos, ids)` serves feature row `ids[k]` from
the cache array when `pos[ids[k]] >= 0` and from the global matrix
otherwise, returning `(rows, hits, misses)` — the counters are computed on
device (`cache_stats`, bit-matched by the numpy mirror
`repro.featcache.plan.cache_stats_np`) so measured hit rates cost no extra
host sync beyond the metrics the trainer already pulls.

`impl="auto"` follows the same rule as `gather_agg`: Pallas on TPU, the
jnp reference elsewhere (interpret mode is a simulator — correct, but for
validation, never CPU throughput). The backward reuses
`gather_agg_bwd_dx_pallas` twice (fanout-1 masked scatter-adds of the
cotangent into cache rows for hits and global rows for misses).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gather_agg.kernel import gather_agg_bwd_dx_pallas
from repro.kernels.gather_cached.kernel import gather_cached_fwd_pallas
from repro.kernels.gather_cached.ref import gather_cached_ref

CACHE_IMPLS = ("auto", "jnp", "pallas")


def resolve_cache_impl(impl: str) -> str:
    """'auto' -> 'pallas' on TPU backends, 'jnp' elsewhere."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl not in ("jnp", "pallas"):
        raise ValueError(
            f"cache impl must be one of {CACHE_IMPLS}, got {impl!r}")
    return impl


def _hit_mask(pos, ids, num_nodes: int):
    gid = jnp.clip(ids, 0, num_nodes - 1)
    sel = pos[gid]
    hit = (sel >= 0) & (ids >= 0) & (ids < num_nodes)
    return gid, sel, hit


def cache_stats(pos, ids, num_nodes: int):
    """Device-side (hits, misses) int32 counters over the VALID entries of
    `ids` (entries outside [0, num_nodes) are padding and count as
    neither). Mirror: `repro.featcache.plan.cache_stats_np`."""
    ids = ids.astype(jnp.int32)
    _, _, hit = _hit_mask(pos, ids, num_nodes)
    valid = (ids >= 0) & (ids < num_nodes)
    hits = jnp.sum(hit, dtype=jnp.int32)
    return hits, jnp.sum(valid, dtype=jnp.int32) - hits


def cache_ref_updates(pos, ids, capacity: int):
    """Per-SLOT hit counts and per-NODE miss counts for one batch of reads
    — the extended device counters behind the dynamic CLOCK admission loop
    (`repro.featcache.dynamic`).

    Returns `(slot_hits (C,) int32, node_miss (N,) int32)` over the VALID
    entries of `ids` (same validity rule as `cache_stats`; their sums equal
    its scalar hits/misses). `slot_hits > 0` is the per-slot reference bit;
    `node_miss` feeds the candidate-frequency accumulator the epoch refill
    admits from. Mirror: `repro.featcache.plan.cache_ref_updates_np`."""
    num_nodes = pos.shape[0]
    ids = ids.astype(jnp.int32)
    gid, sel, hit = _hit_mask(pos, ids, num_nodes)
    valid = (ids >= 0) & (ids < num_nodes)
    slot_hits = jnp.zeros((capacity,), jnp.int32).at[
        jnp.where(hit, sel, capacity)].add(1, mode="drop")
    node_miss = jnp.zeros((num_nodes,), jnp.int32).at[
        jnp.where(valid & ~hit, gid, num_nodes)].add(1, mode="drop")
    return slot_hits, node_miss


def _fwd_pallas(cache, feats, pos, ids, interpret):
    N = feats.shape[0]
    gid, sel, hit = _hit_mask(pos, ids, N)
    # partition hits first: the unselected table's 0-pinned stream is then
    # contiguous, so the pipeline never re-fetches it (see kernel.py)
    order = jnp.argsort(jnp.where(hit, 0, 1)).astype(jnp.int32)
    return gather_cached_fwd_pallas(
        cache, feats,
        crow=jnp.where(hit, sel, 0)[order].astype(jnp.int32),
        frow=jnp.where(hit, 0, gid)[order].astype(jnp.int32),
        hit=hit[order].astype(jnp.int32),
        orow=order,
        interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _gather_cached(cache, feats, pos, ids, interpret):
    return _fwd_pallas(cache, feats, pos, ids, interpret)


def _gather_cached_fwd(cache, feats, pos, ids, interpret):
    out = _fwd_pallas(cache, feats, pos, ids, interpret)
    return out, (cache, feats, pos, ids)


def _gather_cached_bwd(interpret, res, g):
    cache, feats, pos, ids = res
    M = ids.shape[0]
    gid, sel, hit = _hit_mask(pos, ids, feats.shape[0])
    d_cache = gather_agg_bwd_dx_pallas(
        jnp.maximum(sel, 0).reshape(M, 1),
        hit.astype(jnp.float32).reshape(M, 1), g, cache.shape[0],
        interpret=interpret)
    d_feats = gather_agg_bwd_dx_pallas(
        gid.reshape(M, 1),
        (~hit).astype(jnp.float32).reshape(M, 1), g, feats.shape[0],
        interpret=interpret)
    return (d_cache.astype(cache.dtype), d_feats.astype(feats.dtype),
            np.zeros(pos.shape, jax.dtypes.float0),
            np.zeros(ids.shape, jax.dtypes.float0))


_gather_cached.defvjp(_gather_cached_fwd, _gather_cached_bwd)


def gather_cached(cache, feats, pos, ids, *, impl: str = "auto"):
    """Two-level gather: `(rows (M, F) float32, hits, misses)`.

    cache: (C, F) admitted rows (exact copies, so hits are bit-identical
    to global reads); feats: (N, F); pos: (N,) int32 (-1 = miss); ids:
    (M,) int global row ids — entries outside [0, N) are padding, served
    from a clipped global row (mask downstream) and excluded from the
    counters. Differentiable in cache and feats; call inside jit (the
    trainer's step functions already are). The counters are pure jnp
    reductions: a caller that discards them (`apply_gnn` does) pays
    nothing under jit (XLA dead-code-eliminates the unused subgraph), and
    `cache_stats` is the ONE counting rule — the trainer's per-batch
    metering calls the same function.
    """
    impl = resolve_cache_impl(impl)
    ids = ids.astype(jnp.int32)
    hits, misses = cache_stats(pos, ids, feats.shape[0])
    if impl == "jnp":
        return gather_cached_ref(cache, feats, pos, ids), hits, misses
    interpret = jax.default_backend() != "tpu"
    return (_gather_cached(cache, feats, pos, ids, interpret),
            hits, misses)
