"""Pallas TPU kernel: grouped expert matmul (E, C, d) x (E, d, f).

The MoE dispatch packs each expert's tokens into fixed-capacity rows; this
kernel runs the per-expert matmul with d-axis accumulation in the revisited
output block. Grid (E, C/bc, f/bf, d/bd), d innermost sequential.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    di = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0].astype(jnp.float32)                 # (bc, bd)
    w = w_ref[0].astype(jnp.float32)                 # (bd, bf)
    o_ref[...] += jax.lax.dot(x, w)[None].astype(o_ref.dtype)


def moe_gmm_pallas(x, w, *, bc=128, bf=128, bd=128, interpret=False):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f) float32."""
    E, C, d = x.shape
    f = w.shape[2]
    bc, bf, bd = min(bc, C), min(bf, f), min(bd, d)
    assert C % bc == 0 and f % bf == 0 and d % bd == 0
    grid = (E, C // bc, f // bf, d // bd)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), jnp.float32),
        interpret=interpret,
    )(x, w)
