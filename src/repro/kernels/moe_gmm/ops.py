"""jit'd wrapper for the grouped expert matmul kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gmm.kernel import moe_gmm_pallas


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd"))
def moe_gmm(x, w, bc: int = 128, bf: int = 128, bd: int = 128):
    interpret = jax.default_backend() != "tpu"
    return moe_gmm_pallas(x, w, bc=bc, bf=bf, bd=bd, interpret=interpret)
