"""Pallas TPU kernel: fused row-gather + masked mean over the fanout axis.

This is the GNN aggregation hot spot: for each destination node, gather its
`r` sampled neighbors' feature rows from HBM and average them. The neighbor
indices arrive through *scalar prefetch* so the BlockSpec index_map can
stream exactly the needed rows HBM->VMEM (no materialized (D, r, F) tensor).

Grid: (n_dst, r) — the fanout axis is innermost and sequential, accumulating
into the revisited output block; the final step divides by the valid count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, msk_ref, x_ref, o_ref, *, fanout: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    m = msk_ref[i, j].astype(jnp.float32)
    o_ref[...] += x_ref[...].astype(jnp.float32) * m

    @pl.when(j == fanout - 1)
    def _finish():
        cnt = jnp.float32(0)
        for jj in range(fanout):
            cnt += msk_ref[i, jj].astype(jnp.float32)
        o_ref[...] = o_ref[...] / jnp.maximum(cnt, 1.0)


def gather_mean_pallas(x, idx, mask, *, interpret: bool = False):
    """x: (N, F) float32; idx: (D, r) int32 (rows of x); mask: (D, r) int32.

    Returns (D, F) float32 masked means. F should be a multiple of 128 on
    real TPUs (lane width); interpret mode accepts any F.
    """
    D, r = idx.shape
    F = x.shape[1]
    grid = (D, r)
    kernel = functools.partial(_kernel, fanout=r)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, F), lambda i, j, idx_ref, msk_ref:
                             (idx_ref[i, j], 0)),
            ],
            out_specs=pl.BlockSpec((1, F), lambda i, j, idx_ref, msk_ref:
                                   (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((D, F), jnp.float32),
        interpret=interpret,
    )(idx, mask, x)
