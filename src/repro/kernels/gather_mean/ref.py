"""Pure-jnp oracle for the gather_mean kernel."""
import jax.numpy as jnp


def gather_mean_ref(x, idx, mask):
    g = x[jnp.clip(idx, 0, x.shape[0] - 1)].astype(jnp.float32)
    m = mask.astype(jnp.float32)[..., None]
    s = (g * m).sum(axis=1)
    cnt = jnp.maximum(m.sum(axis=1), 1.0)
    return s / cnt
