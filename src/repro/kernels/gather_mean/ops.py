"""jit'd public wrapper: Pallas on TPU, interpret-mode kernel on CPU."""
from __future__ import annotations

import functools

import jax

from repro.kernels.gather_mean.kernel import gather_mean_pallas
from repro.kernels.gather_mean.ref import gather_mean_ref


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def gather_mean(x, idx, mask, use_kernel: bool = True):
    if not use_kernel:
        return gather_mean_ref(x, idx, mask)
    interpret = jax.default_backend() != "tpu"
    return gather_mean_pallas(x, idx.astype("int32"), mask.astype("int32"),
                              interpret=interpret)
