"""DEPRECATED import location — `gather_mean` is now a thin shim over the
generalized `repro.kernels.gather_agg` fused kernel (masked mean == weighted
sum with w = mask / count, counts precomputed OUTSIDE the kernel — which
also retires the old kernel's O(fanout^2) unrolled `_finish` re-count).
Kept for existing callers, mirroring the `CommRandPolicy` shim in
`repro.configs.base`; new code should call `gather_agg` directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_agg.ops import gather_agg
from repro.kernels.gather_mean.ref import gather_mean_ref


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def gather_mean(x, idx, mask, use_kernel: bool = True):
    """x: (N, F) float32; idx: (D, r) int32 (rows of x); mask: (D, r) bool.

    Returns (D, F) float32 masked means (all-masked rows are zero)."""
    if not use_kernel:
        return gather_mean_ref(x, idx, mask)
    m = mask.astype(jnp.float32)
    w = m / jnp.maximum(m.sum(axis=1, keepdims=True), 1.0)
    return gather_agg(x, idx, w, impl="pallas")
