"""Public fused gather-aggregate op with a custom VJP.

`gather_agg(x, idx, w)` computes `out[i] = sum_j w[i,j] * x[idx[i,j]]`
without ever materializing the (n_dst, r, F) gathered intermediate — in
either direction: the forward is the multi-row-tiled Pallas gather-reduce,
the backward is a Pallas scatter-add for dx plus a fused gather-dot for dw
(see `kernel.py`). `impl="jnp"` falls back to the XLA reference
(`ref.gather_agg_ref`) with native autodiff; `impl="auto"` picks the Pallas
kernel on TPU and the jnp path elsewhere (interpret mode is a simulator —
correct, but only for validation, never for CPU throughput).

Model code selects the path via `GNNConfig.agg_impl`; `resolve_agg_impl`
is the single place the "auto" policy lives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gather_agg.kernel import (gather_agg_bwd_dw_pallas,
                                             gather_agg_bwd_dx_pallas,
                                             gather_agg_fwd_pallas)
from repro.kernels.gather_agg.ref import gather_agg_ref

AGG_IMPLS = ("auto", "jnp", "pallas")


def resolve_agg_impl(impl: str) -> str:
    """'auto' -> 'pallas' on TPU backends, 'jnp' elsewhere."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl not in ("jnp", "pallas"):
        raise ValueError(f"agg_impl must be one of {AGG_IMPLS}, got {impl!r}")
    return impl


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _gather_agg(x, idx, w, block_dst, interpret):
    return gather_agg_fwd_pallas(x, idx, w, block_dst=block_dst,
                                 interpret=interpret)


def _gather_agg_fwd(x, idx, w, block_dst, interpret):
    out = gather_agg_fwd_pallas(x, idx, w, block_dst=block_dst,
                                interpret=interpret)
    return out, (x, idx, w)


def _gather_agg_bwd(block_dst, interpret, res, g):
    x, idx, w = res
    dx = gather_agg_bwd_dx_pallas(idx, w, g, x.shape[0],
                                  interpret=interpret)
    dw = gather_agg_bwd_dw_pallas(x, idx, g, interpret=interpret)
    didx = np.zeros(idx.shape, jax.dtypes.float0)
    return dx.astype(x.dtype), didx, dw.astype(w.dtype)


_gather_agg.defvjp(_gather_agg_fwd, _gather_agg_bwd)


def gather_agg(x, idx, w, *, impl: str = "pallas", block_dst: int = 8):
    """Fused `out[i] = sum_j w[i,j] * x[idx[i,j]]`; differentiable in x, w.

    x: (n_src, F) float; idx: (n_dst, r) int (clipped to [0, n_src));
    w: (n_dst, r) float. Returns (n_dst, F) float32. Call inside jit (the
    trainer's step functions already are); no jit wrapper here so the
    kernel inlines into the surrounding step.
    """
    impl = resolve_agg_impl(impl)
    if impl == "jnp":
        return gather_agg_ref(x, idx, w)
    interpret = jax.default_backend() != "tpu"
    idx = jnp.clip(idx.astype(jnp.int32), 0, x.shape[0] - 1)
    return _gather_agg(x, idx, w.astype(jnp.float32), block_dst, interpret)
