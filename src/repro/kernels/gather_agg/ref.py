"""Pure-jnp oracle for the fused gather-aggregate kernel.

`gather_agg_ref` is also the production `agg_impl="jnp"` path: XLA fuses the
gather with the weighted reduce reasonably well on CPU/GPU, but it still
materializes the (n_dst, fanout, F) intermediate the Pallas kernel avoids.
"""
import jax.numpy as jnp


def gather_agg_ref(x, idx, w):
    """out[i] = sum_j w[i, j] * x[idx[i, j]].

    x: (n_src, F) float; idx: (n_dst, r) int (clipped to valid rows);
    w: (n_dst, r) float per-edge weights (0 for masked slots).
    Returns (n_dst, F) float32.
    """
    g = x[jnp.clip(idx, 0, x.shape[0] - 1)].astype(jnp.float32)
    return (g * w.astype(jnp.float32)[..., None]).sum(axis=1)
