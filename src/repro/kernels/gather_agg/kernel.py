"""Pallas TPU kernels: fused gather + per-edge-weighted reduce, and its
backward pair (scatter-add into dx, per-edge row dots for dw).

This is the GNN aggregation hot spot the paper's working-set argument is
about: for each destination node, gather its `r` sampled neighbors' feature
rows from HBM and reduce them under per-edge weights

    out[i] = sum_j w[i, j] * x[idx[i, j]]

One kernel therefore lowers SAGE's masked mean (w = mask / count), GCN's
symmetric-normalized weighted sum (w folds the degree normalizers), and
GAT's alpha-weighted value reduction (w = attention weights) — the weights
are always computed OUTSIDE the kernel, on (n_dst, r) scalars, so nothing
(n_dst, r, F)-shaped ever touches HBM.

Forward grid: (n_dst / bd, bd, r) — destination rows are tiled in blocks of
`bd` (the f32 sublane width by default), so each output tile is written back
to HBM once per bd*r steps instead of once per r steps as in the old 1-row
`gather_mean` grid. Neighbor indices and weights arrive through *scalar
prefetch* so the x BlockSpec index_map streams exactly the needed rows
HBM->VMEM, double-buffered by the pipeline.

Backward dx grid: one step per edge, with edges PRE-SORTED by source row
(a cheap (n_dst*r,) argsort outside the kernel). Sorting makes the output
index map non-decreasing, so every revisit of a dx row is consecutive — the
only accumulation pattern Pallas guarantees (a block stays resident in VMEM
while its index repeats, and is written back exactly once when it changes).
Rows that receive no edge keep the zeros of the aliased input buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# forward: out[i] = sum_j w[i, j] * x[idx[i, j]]
# ---------------------------------------------------------------------------
def _fwd_kernel(idx_ref, w_ref, x_ref, o_ref, *, bd: int):
    del idx_ref  # consumed by the BlockSpec index maps
    i, ii, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((ii == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[i * bd + ii, j]
    row = o_ref[pl.ds(ii, 1), :]
    o_ref[pl.ds(ii, 1), :] = row + x_ref[...].astype(jnp.float32) * w


def gather_agg_fwd_pallas(x, idx, w, *, block_dst: int = 8,
                          interpret: bool = False):
    """x: (n_src, F); idx: (n_dst, r) int32 in [0, n_src); w: (n_dst, r)
    float32. Returns (n_dst, F) float32. F should be a multiple of 128 on
    real TPUs (lane width); interpret mode accepts any F."""
    D, r = idx.shape
    F = x.shape[1]
    bd = max(1, min(block_dst, D))
    Dp = ((D + bd - 1) // bd) * bd
    if Dp != D:                      # padded rows gather row 0 with weight 0
        idx = jnp.pad(idx, ((0, Dp - D), (0, 0)))
        w = jnp.pad(w, ((0, Dp - D), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, bd=bd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(Dp // bd, bd, r),
            in_specs=[
                pl.BlockSpec((1, F), lambda i, ii, j, idx_ref, w_ref:
                             (idx_ref[i * bd + ii, j], 0)),
            ],
            out_specs=pl.BlockSpec((bd, F), lambda i, ii, j, idx_ref, w_ref:
                                   (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((Dp, F), jnp.float32),
        interpret=interpret,
    )(idx, w, x)
    return out[:D] if Dp != D else out


# ---------------------------------------------------------------------------
# backward dx: dx[idx[i, j]] += w[i, j] * g[i]  (edges sorted by src row)
# ---------------------------------------------------------------------------
def _bwd_dx_kernel(src_ref, dst_ref, w_ref, g_ref, dx0_ref, o_ref):
    del dst_ref, dx0_ref
    e = pl.program_id(0)
    new_run = (e == 0) | (src_ref[e] != src_ref[jnp.maximum(e - 1, 0)])

    @pl.when(new_run)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += g_ref[...].astype(jnp.float32) * w_ref[e]


def gather_agg_bwd_dx_pallas(idx, w, g, n_src: int, *,
                             interpret: bool = False):
    """Scatter-add cotangents back to the gathered rows.

    idx/w: (n_dst, r); g: (n_dst, F) cotangent. Returns (n_src, F) float32.
    The edge list is sorted by source row outside the kernel so accumulation
    runs are consecutive (see module docstring)."""
    D, r = idx.shape
    F = g.shape[1]
    E = D * r
    flat = idx.reshape(-1)
    order = jnp.argsort(flat).astype(jnp.int32)
    src_sorted = flat[order].astype(jnp.int32)
    dst_sorted = (order // r).astype(jnp.int32)
    w_sorted = w.reshape(-1)[order].astype(jnp.float32)
    dx0 = jnp.zeros((n_src, F), jnp.float32)
    return pl.pallas_call(
        _bwd_dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(E,),
            in_specs=[
                pl.BlockSpec((1, F), lambda e, s, d, w: (d[e], 0)),
                pl.BlockSpec((1, F), lambda e, s, d, w: (s[e], 0)),
            ],
            out_specs=pl.BlockSpec((1, F), lambda e, s, d, w: (s[e], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_src, F), jnp.float32),
        input_output_aliases={4: 0},     # untouched rows keep dx0's zeros
        interpret=interpret,
    )(src_sorted, dst_sorted, w_sorted, g, dx0)


# ---------------------------------------------------------------------------
# backward dw: dw[i, j] = <g[i], x[idx[i, j]]>
# ---------------------------------------------------------------------------
def _bwd_dw_kernel(idx_ref, x_ref, g_ref, o_ref):
    del idx_ref
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dot = jnp.sum(x_ref[...].astype(jnp.float32) *
                  g_ref[...].astype(jnp.float32))
    lane = jax.lax.broadcasted_iota(jnp.int32, o_ref.shape, 1)
    o_ref[...] += jnp.where(lane == j, dot, 0.0)


def gather_agg_bwd_dw_pallas(x, idx, g, *, interpret: bool = False):
    """Per-edge weight cotangents (needed when w carries gradient, e.g. GAT
    attention): fused gather + row dot. The (D, r) output is padded to the
    128-lane width and written as one revisited (1, lanes) row tile per dst
    (fanout is the inner, consecutive grid axis), keeping the store aligned
    with TPU tiling. Dead-code-eliminated by XLA when dw is unused
    (SAGE/GCN)."""
    D, r = idx.shape
    F = x.shape[1]
    rp = ((r + 127) // 128) * 128
    out = pl.pallas_call(
        _bwd_dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(D, r),
            in_specs=[
                pl.BlockSpec((1, F), lambda i, j, idx_ref:
                             (idx_ref[i, j], 0)),
                pl.BlockSpec((1, F), lambda i, j, idx_ref: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, rp), lambda i, j, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((D, rp), jnp.float32),
        interpret=interpret,
    )(idx, x, g)
    return out[:, :r] if rp != r else out
