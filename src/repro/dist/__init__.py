"""Distributed-execution utilities (sharding rules, mesh contexts).

`repro.dist.sharding` holds the generic FSDP×TP spec machinery (LM
side); `repro.dist.gnn` is the data-parallel GNN path: community-
partitioned feature sharding, per-epoch halo planning, the sharded
batch stream and the psum-reduced `shard_map` train step. `gnn` is
imported lazily (via this module's `__getattr__`) so importing
`repro.dist` stays cheap for LM-only consumers.
"""


def __getattr__(name):
    if name in ("gnn", "sharding"):
        import importlib
        return importlib.import_module(f"repro.dist.{name}")
    raise AttributeError(f"module 'repro.dist' has no attribute {name!r}")
