"""Distributed-execution utilities (sharding rules, mesh contexts)."""
