"""Data-parallel GNN training on a community-partitioned device mesh.

COMM-RAND's community structure is the sharding key: the (N, F) feature
matrix is partitioned so every community lives wholly inside one shard
(communities <-> shards), each replica consumes a slice of the ONE global
counter-based epoch order, and cross-shard neighbor features move through
`core.halo` ring exchanges planned per epoch from that order. Gradients
are `psum`-reduced inside the jitted `shard_map` step, so D replicas
train one model.

Determinism contract (what the tests pin):

  * the global root order is the single source of truth — replica r's
    roots for global batch `pos` are `order[pos][r*Bs:(r+1)*Bs]`, so the
    per-replica streams CONCATENATE to the exact single-device epoch
    order, and `Cursor(epoch, pos)` semantics (checkpoint/resume) are
    unchanged;
  * every replica builds its sub-batch with the SAME `(seed, epoch,
    pos)`-derived key (the cooperative-minibatching choice: shared
    sampling randomness across replicas, arXiv:2310.12403);
  * the sharded loss is `sum_r nll_r / max(psum(mask_r), 1)` — at D=1
    every collective is an identity, so a 1-replica mesh run is
    BIT-identical to the single-device `train_step` (loss trajectory and
    params digest, asserted by tests/test_dist_gnn.py);
  * halo-gathered rows are bit-copies of the global feature rows (the
    partition is a relabeling, `ShardPlan.shard_pos` a bijection), so
    sharding never perturbs the numerics of a feature read.

Halo planning: `plan_halo` computes, from the epoch's root slices and
the graph's shard-adjacency reachability (an over-approximation of any
L-hop sampled neighborhood, so the budget is always sufficient), the
ring distance each replica needs; `r_cap = cap_L` makes the exchange
provably dropless (one replica requests at most cap_L rows total, so no
single neighbor can see more). When the predicted halo bytes exceed the
all-gather fallback, the plan degrades to `mode="global"`. Plans are
frozen dataclasses: `GNNTrainer` re-plans at epoch boundaries and reuses
the jitted step whenever the plan is unchanged (the recompile-stability
contract `analysis.jaxpr_audit.audit_sharded_step` gates).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.batching.stream import BatchStream
from repro.core import halo
from repro.core import minibatch as mb
from repro.dist.sharding import shard_map
from repro.graphs.csr import Graph

AXIS = "shard"


def make_gnn_mesh(n_shards: Optional[int] = None) -> Mesh:
    """1-D ("shard",) mesh over the first `n_shards` devices (default:
    all). CI simulates multi-host with
    XLA_FLAGS=--xla_force_host_platform_device_count=4."""
    devs = jax.devices()
    n = len(devs) if n_shards is None else n_shards
    if len(devs) < n:
        raise RuntimeError(f"mesh needs {n} devices, found {len(devs)}")
    return Mesh(np.asarray(devs[:n]), (AXIS,))


# ---------------------------------------------------------------------------
# community-aligned feature partition
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardPlan:
    """Community-aligned node partition for a D-shard mesh.

    `shard_pos` is a BIJECTION from global node ids onto distinct slots
    of the padded (D * n_per_shard) local-slot space: node i lives at
    local slot `shard_pos[i] - owner*n_per_shard` of shard
    `owner = shard_pos[i] // n_per_shard`. `perm` inverts it
    (`perm[shard_pos[i]] == i`; padding slots hold -1). Communities are
    never split across shards, so COMM-RAND's community-pure batches
    keep their feature reads shard-local."""
    n_shards: int
    n_nodes: int
    n_per_shard: int
    shard_pos: np.ndarray        # (N,) int32 global id -> padded slot
    perm: np.ndarray             # (D * n_per_shard,) int64 slot -> id | -1
    shard_of_comm: np.ndarray    # (n_comm,) int32

    @property
    def n_padded(self) -> int:
        return self.n_shards * self.n_per_shard

    @property
    def shard_of_node(self) -> np.ndarray:
        return (self.shard_pos // self.n_per_shard).astype(np.int32)

    def shard_features(self, features: np.ndarray, mesh: Mesh):
        """Pad + permute the (N, F) matrix into its (D * Ns, F) sharded
        layout (padding slots are zero rows) and device_put it
        P("shard", None). Rows are bit-copies: `local[shard_pos[i]] ==
        features[i]` exactly."""
        feats = np.asarray(features)
        out = np.zeros((self.n_padded, feats.shape[1]), feats.dtype)
        valid = self.perm >= 0
        out[valid] = feats[self.perm[valid]]
        return jax.device_put(
            jnp.asarray(out), NamedSharding(mesh, P(AXIS, None)))

    def device_pos(self, mesh: Mesh):
        """The (N,) id->slot map, replicated (rides into the jitted
        sharded step as an argument, never a baked constant)."""
        return jax.device_put(
            jnp.asarray(self.shard_pos, jnp.int32),
            NamedSharding(mesh, P()))


def community_shard_plan(graph: Graph, n_shards: int) -> ShardPlan:
    """Greedy balanced assignment of whole communities to shards.

    Communities are sorted by size (largest first) and dealt to the
    least-loaded shard; within a shard, nodes keep ascending global-id
    order (after `core.reorder.prepare` that is the community-contiguous
    degree order). D=1 degenerates to the identity relabeling."""
    if graph.communities is None:
        raise ValueError("graph has no communities — run "
                         "core.reorder.prepare first")
    comm = np.asarray(graph.communities, np.int64)
    n_comm = int(comm.max()) + 1 if len(comm) else 0
    sizes = np.bincount(comm, minlength=n_comm)
    shard_of_comm = np.zeros(n_comm, np.int32)
    load = np.zeros(n_shards, np.int64)
    for c in np.argsort(-sizes, kind="stable"):
        s = int(np.argmin(load))
        shard_of_comm[c] = s
        load[s] += sizes[c]
    n_per_shard = int(load.max()) if n_shards > 1 else graph.num_nodes
    owner = shard_of_comm[comm]
    shard_pos = np.zeros(graph.num_nodes, np.int32)
    perm = np.full(n_shards * n_per_shard, -1, np.int64)
    for s in range(n_shards):
        ids = np.nonzero(owner == s)[0]          # ascending global ids
        slots = s * n_per_shard + np.arange(len(ids))
        shard_pos[ids] = slots
        perm[slots] = ids
    return ShardPlan(n_shards, graph.num_nodes, n_per_shard,
                     shard_pos, perm, shard_of_comm)


# ---------------------------------------------------------------------------
# per-epoch halo planning
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HaloPlan:
    """Static exchange budget for one epoch's sharded feature gathers.
    Frozen + hashable: the jitted step is cached per plan, so epochs
    with identical plans never retrace."""
    mode: str                    # "halo" | "global"
    halo: int                    # ring distance (0 at D=1)
    r_cap: int                   # request slots per neighbor (= cap_L)

    def bytes_per_gather(self, cap_l: int, feat_dim: int,
                         n_shards: int) -> int:
        return halo.collective_bytes_model(
            cap_l, feat_dim, n_shards, self.r_cap, self.halo, self.mode)


def _ring_dist(a: np.ndarray, b: np.ndarray, d: int) -> np.ndarray:
    fwd = (a - b) % d
    return np.minimum(fwd, d - fwd)


def shard_adjacency(graph: Graph, plan: ShardPlan) -> np.ndarray:
    """(D, D) bool: shard s has an edge into shard t — the 1-hop
    over-approximation any sampled neighborhood is a subset of."""
    d = plan.n_shards
    owner = plan.shard_of_node
    src = np.repeat(np.arange(graph.num_nodes),
                    np.diff(graph.indptr).astype(np.int64))
    adj = np.zeros((d, d), bool)
    adj[owner[src], owner[graph.indices]] = True
    adj |= np.eye(d, dtype=bool)
    return adj


def plan_halo(plan: ShardPlan, graph: Graph, fanouts, cap_l: int,
              root_batches: Optional[np.ndarray] = None,
              mode: str = "auto") -> HaloPlan:
    """Pick (mode, halo, r_cap) for one epoch.

    `root_batches` is the epoch's (n_batches, B) global root order (from
    `ShardedBatchStream.root_batches`); each replica's required ring
    distance is the max distance from ITS index to any shard reachable
    in L hops from the owner shards of ITS root slices. None plans for
    the worst case (all shards rooted everywhere). `r_cap = cap_l` makes
    the halo exchange dropless by construction: a replica requests at
    most cap_l rows total, so no one neighbor can be asked for more."""
    d = plan.n_shards
    if d == 1:
        return HaloPlan("halo", 0, cap_l)
    reach = shard_adjacency(graph, plan)
    hops = np.eye(d, dtype=bool)
    for _ in range(len(fanouts)):
        hops = hops @ reach
    owner = plan.shard_of_node
    need = 0
    if root_batches is None:
        rooted = np.ones((d, d), bool)           # replica r roots anywhere
    else:
        rb = np.asarray(root_batches)
        bs = rb.shape[1] // d
        rooted = np.zeros((d, d), bool)
        for r in range(d):
            roots = rb[:, r * bs:(r + 1) * bs].reshape(-1)
            roots = roots[roots >= 0]
            rooted[r, np.unique(owner[roots])] = True
    targets = rooted @ hops                      # (replica, owner-shard)
    for r in range(d):
        ts = np.nonzero(targets[r])[0]
        if len(ts):
            need = max(need, int(_ring_dist(np.full(len(ts), r), ts,
                                            d).max()))
    hp = HaloPlan("halo", need, cap_l)
    if mode == "auto":
        if hp.bytes_per_gather(cap_l, graph.feat_dim, d) > \
                HaloPlan("global", 0, 0).bytes_per_gather(
                    cap_l, graph.feat_dim, d):
            hp = HaloPlan("global", 0, 0)
    elif mode == "global":
        hp = HaloPlan("global", 0, 0)
    return hp


# ---------------------------------------------------------------------------
# sharded batch stream: D sub-batches from ONE global order
# ---------------------------------------------------------------------------
class ShardedBatchStream(BatchStream):
    """`BatchStream` whose compiled batches carry a leading shard axis.

    The epoch order, `num_batches`, cursor and key derivations are the
    base class's — bit-identical to single-device. Only `build` changes:
    the (B,) global root batch is dealt as D contiguous (B/D,) slices
    (slice r -> replica r), each built through the SAME shape-generic
    `_build_batch` with the SAME (epoch, pos) key, and the D sub-batch
    pytrees are stacked and device_put P("shard", ...). Concatenating
    the replica slices reconstructs the global order exactly
    (`replica_root_batches`)."""

    def __init__(self, *args, mesh: Mesh, plan: ShardPlan, **kwargs):
        super().__init__(*args, **kwargs)
        if self.batch_size % plan.n_shards:
            raise ValueError(
                f"batch_size {self.batch_size} not divisible by "
                f"{plan.n_shards} shards")
        self.mesh = mesh
        self.plan = plan
        self._batch_sharding = NamedSharding(mesh, P(AXIS))

    def replica_root_batches(self, epoch: int) -> np.ndarray:
        """(n_batches, D, B/D) per-replica root slices; concatenated
        over the replica axis they equal `root_batches(epoch)`."""
        rb = self.root_batches(epoch)
        d = self.plan.n_shards
        return rb.reshape(rb.shape[0], d, self.batch_size // d)

    def build(self, roots: np.ndarray, epoch: int, pos: int) -> mb.MiniBatch:
        d = self.plan.n_shards
        bs = self.batch_size // d
        key = self.batch_key(epoch, pos)
        ekey = self.epoch_key(epoch)
        ctx = self.epoch_ctx(epoch)
        subs = [mb._build_batch(
            key, ekey, self.g,
            jnp.asarray(roots[r * bs:(r + 1) * bs], jnp.int32),
            self.labels, self.fanouts, self.caps, self.sampler, ctx)
            for r in range(d)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *subs)
        return jax.tree.map(
            lambda x: jax.device_put(x, self._batch_sharding), stacked)


# ---------------------------------------------------------------------------
# the jitted sharded step
# ---------------------------------------------------------------------------
def gather_batch_features(feats_local, shard_pos, ids, plan: ShardPlan,
                          hplan: HaloPlan, cache=None, axis: str = AXIS):
    """Inside shard_map: serve `ids` (global node ids, sentinel >= N ->
    zero rows) as exact bit-copies of the global feature rows — cache
    hits from the replicated cache rows, everything else through the
    planned `core.halo` exchange on the remapped shard-slot ids.
    Returns ((K, F) rows, dropped count)."""
    n, npad = plan.n_nodes, plan.n_padded
    valid = ids < n
    cid = jnp.minimum(ids, n - 1)
    rid = jnp.where(valid, shard_pos[cid], npad)
    cpos = None
    if cache is not None:
        cpos = cache.pos[cid]
        hit = valid & (cpos >= 0)
        rid = jnp.where(hit, npad, rid)          # hits stay off the wire
    rows, dropped = halo.gather_for_policy(
        feats_local, rid, n_per_shard=plan.n_per_shard,
        r_cap=hplan.r_cap, halo=hplan.halo, axis=axis, mode=hplan.mode)
    if cache is not None:
        crow = cache.cache[jnp.maximum(cpos, 0)]
        rows = jnp.where(hit[:, None], crow, rows)
    return rows, dropped


def sharded_softmax_ce(logits, labels, mask, axis: str = AXIS):
    """`train.losses.gnn_softmax_ce` with the mask count psum-reduced:
    the per-replica value is this replica's share of the GLOBAL masked
    mean, so `psum(loss_r)` equals the single-device loss and
    `psum(grad_r)` its gradient. At D=1 the psum is an identity and the
    expression is bit-for-bit `gnn_softmax_ce`."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    nll = (lse - picked) * mask
    return nll.sum() / jnp.maximum(lax.psum(mask.sum(), axis), 1.0)


def make_sharded_steps(cfg, tcfg, mesh: Mesh, plan: ShardPlan,
                       hplan: HaloPlan, *, donate: Optional[bool] = None,
                       axis: str = AXIS):
    """Build the jitted data-parallel train step.

    Same 10-argument signature as the single-device
    `train.gnn_loop._make_steps` train step, with two layout changes the
    trainer owns: `batch` leaves carry a leading shard axis
    (`ShardedBatchStream`), and `feats` is the dict
    {"local": (D*Ns, F) P("shard", None), "pos": (N,) replicated}. The
    dropout key is passed as raw `jax.random.key_data` bits (wrapped
    back inside the step) so PRNG-key dtypes never meet shard_map specs.
    Returns `(params, opt, loss, ok, skips, hits, misses, aux)` where
    `aux` is a per-replica dict (leaves shaped (D,)): per-replica loss
    share, halo-dropped count, cache hit/miss counters — the per-replica
    observability feed. `hplan` is static: one compiled step per plan."""
    from repro.featcache.plan import CachePlan
    from repro.kernels.gather_cached.ops import cache_stats
    from repro.models.gnn.models import apply_gnn
    from repro.optim import adamw

    if donate is None:
        donate = jax.default_backend() != "cpu"
    n = plan.n_nodes

    def per_replica(params, opt_state, batch, feats, degrees, lr,
                    key_data, cache, poison, skips):
        b = jax.tree.map(lambda x: x[0], batch)  # strip the shard axis
        key = jax.random.wrap_key_data(key_data)
        rows, dropped = gather_batch_features(
            feats["local"], feats["pos"], b.node_ids, plan, hplan,
            cache=cache, axis=axis)

        def loss_fn(p):
            # apply_gnn masks the table by node_mask itself; rows at
            # invalid (sentinel) positions are already zero
            logits = apply_gnn(cfg, p, b, rows, degrees, train=True,
                               dropout_key=key, feats_global=False,
                               cache=None)
            return sharded_softmax_ce(
                logits, b.labels, b.label_mask.astype(jnp.float32),
                axis) * poison

        loss_r, grads_r = jax.value_and_grad(loss_fn)(params)
        grads = jax.tree.map(lambda g: lax.psum(g, axis), grads_r)
        loss = lax.psum(loss_r, axis)
        # in-jit guard (repro.resilience): grads are psum'd, so the
        # verdict — and the where-select below — is identical on every
        # replica; no replica can diverge from the others' params
        ok = jnp.isfinite(loss)
        for g in jax.tree.leaves(grads):
            ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(g)))
        new_params, new_opt = adamw.update(
            grads, opt_state, params, lr=lr,
            weight_decay=tcfg.weight_decay)

        def keep(new, old):
            return jax.tree.map(lambda a, o: jnp.where(ok, a, o), new, old)

        new_params = keep(new_params, params)
        new_opt = keep(new_opt, opt_state)
        skips = jnp.where(ok, jnp.int32(0), skips + jnp.int32(1))
        if cache is not None:
            h_r, m_r = cache_stats(cache.pos, b.node_ids, n)
        else:
            h_r = m_r = jnp.int32(0)
        hits = lax.psum(h_r, axis)
        misses = lax.psum(m_r, axis)
        aux = {"loss": loss_r[None], "dropped": dropped[None],
               "hits": h_r[None], "misses": m_r[None]}
        return (new_params, new_opt, loss, ok, skips, hits, misses, aux)

    rep, sh = P(), P(axis)
    feats_spec = {"local": P(axis, None), "pos": rep}
    in_specs = (rep, rep, sh, feats_spec, rep, rep, rep, rep, rep, rep)
    out_specs = (rep, rep, rep, rep, rep, rep, rep,
                 {"loss": sh, "dropped": sh, "hits": sh, "misses": sh})
    mapped = shard_map(per_replica, mesh, in_specs, out_specs)
    step = jax.jit(mapped, donate_argnums=(0, 1) if donate else ())

    def train_step(params, opt_state, batch, feats, degrees, lr, key,
                   cache, poison, skips):
        if cache is not None and not isinstance(cache, CachePlan):
            raise ValueError(
                "sharded training supports a static CachePlan only "
                f"(got {type(cache).__name__}); dynamic admission is a "
                "single-device feature for now")
        return step(params, opt_state, batch, feats, degrees, lr,
                    jax.random.key_data(key), cache, poison, skips)

    train_step.mapped = mapped        # undonated: what the audit traces
    return train_step


def replicate(tree, mesh: Mesh):
    """device_put every leaf fully replicated on the mesh."""
    s = NamedSharding(mesh, P())
    return jax.tree.map(lambda x: jax.device_put(x, s), tree)


def state_shardings(state, mesh: Mesh):
    """Replicated NamedSharding tree for a checkpoint state dict — what
    `train.checkpoint.restore(..., shardings=)` device_puts restored
    leaves with (sharded resume)."""
    s = NamedSharding(mesh, P())
    return jax.tree.map(lambda _: s, state)


# ---------------------------------------------------------------------------
# per-replica observability (distinct Perfetto pid per replica)
# ---------------------------------------------------------------------------
class ReplicaTraceEmitter:
    """Re-emit the lockstep step schedule as one Perfetto track per
    replica (`Tracer.for_replica` pid views), fed from the sharded
    step's per-replica aux outputs.

    The SPMD step is dispatched once for all replicas, so each replica's
    step intervals are the host dispatch intervals; what distinguishes
    the tracks is the per-replica payload (loss share, halo drops, cache
    counters). `note` records host timestamps only (never syncs);
    `flush` is called at the trainer's existing epoch boundary AFTER its
    drain, converts the accumulated aux (one small host transfer of
    already-computed (D,) arrays) and emits per-replica "train_step"
    spans plus the boundary "epoch_flush" sync span — placed so every
    replica's trace passes the per-pid mid-epoch-sync gate exactly when
    the host trace does."""

    def __init__(self, n_replicas: int, hplan: HaloPlan, cap_l: int,
                 feat_dim: int):
        self.n = n_replicas
        self._steps = []            # (ts_us, dur_us, step)
        self._aux = []
        self._halo_bytes = hplan.bytes_per_gather(
            cap_l, feat_dim, n_replicas)

    def note(self, ts_us: float, dur_us: float, step: int, aux) -> None:
        self._steps.append((ts_us, dur_us, step))
        self._aux.append(aux)

    def flush(self, tracer, epoch) -> None:
        steps, self._steps = self._steps, []
        aux, self._aux = self._aux, []
        if tracer is None or not steps:
            return
        loss = np.stack([np.asarray(a["loss"]) for a in aux])    # (n, D)
        drop = np.stack([np.asarray(a["dropped"]) for a in aux])
        hits = np.stack([np.asarray(a["hits"]) for a in aux])
        miss = np.stack([np.asarray(a["misses"]) for a in aux])
        t0 = steps[0][0]
        end = max(ts + dur for ts, dur, _ in steps)
        for r in range(self.n):
            v = tracer.for_replica(r)
            for (ts, dur, step), l in zip(steps, loss[:, r]):
                v.emit_span("train_step", "step", ts, dur,
                            step=step, loss_share=float(l))
            v.emit_span("epoch", "loop", t0, end - t0 + 1.0, epoch=epoch)
            v.emit_span("epoch_flush", "sync", end, 1.0, epoch=epoch,
                        n_steps=len(steps))
            v.instant("replica_rollup", cat="device", epoch=epoch,
                      n_steps=len(steps),
                      loss_share=float(loss[:, r].sum()),
                      halo_dropped=int(drop[:, r].sum()),
                      halo_bytes=int(self._halo_bytes * len(steps)),
                      cache_hits=int(hits[:, r].sum()),
                      cache_misses=int(miss[:, r].sum()))


__all__ = [
    "AXIS", "HaloPlan", "ReplicaTraceEmitter", "ShardPlan",
    "ShardedBatchStream", "community_shard_plan", "gather_batch_features",
    "make_gnn_mesh", "make_sharded_steps", "plan_halo", "replicate",
    "shard_adjacency", "sharded_softmax_ce", "state_shardings",
]
