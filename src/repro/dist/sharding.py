"""FSDPxTP sharding rules + activation constraints.

One module owns every sharding decision:

  * `param_specs` / `param_shardings` — name-based PartitionSpecs for the
    transformer param tree (column-parallel up-projections, row-parallel
    down-projections, vocab-parallel embedding/head, expert-parallel MoE).
  * `batch_specs` / `cache_specs` — input and KV-cache layouts per strategy
    ("fsdp" for training, "tp_sp" for serving).
  * the `act_*` family — activation sharding constraints the model code
    sprinkles on residuals / heads / MoE dispatch. They are NO-OPS outside a
    `use_mesh` context, so the same model code runs single-device CPU smoke
    tests and the 512-chip dry-run.

Every proposed spec passes through `_fit`, a divisibility filter: a mesh
axis that does not evenly divide its dimension is dropped (that dim stays
replicated) instead of erroring. This is what lets e.g. a (B, 1, d) decode
residual reuse the sequence-parallel train spec, or a 1-KV-head model skip
head sharding, without per-arch special cases.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# mesh axes that carry the (pure or fully-sharded) data-parallel dimension
_DATA_AXES = ("pod", "data")
_MODEL_AXIS = "model"


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


class ShardCtx:
    """Resolved sharding context for one mesh + strategy.

    strategy: "fsdp" (training: batch over data axes, params FSDP-sharded)
              "tp_sp" (serving: tensor-parallel with sequence-parallel
              residuals). Activation constraints consult the active ctx.
    """

    def __init__(self, mesh: Mesh, strategy: Optional[str] = None):
        self.mesh = mesh
        self.strategy = strategy or "fsdp"
        sizes = _axis_sizes(mesh)
        self.data_axes: Tuple[str, ...] = tuple(
            a for a in mesh.axis_names if a in _DATA_AXES)
        self.model_axis = _MODEL_AXIS if _MODEL_AXIS in sizes else None
        self.fsdp = int(np.prod([sizes[a] for a in self.data_axes])) \
            if self.data_axes else 1
        self.tp = int(sizes.get(_MODEL_AXIS, 1))

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """PartitionSpec entry for a batch dimension."""
        return self.data_axes

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names)


_STATE = threading.local()


def active() -> Optional[ShardCtx]:
    """The innermost `use_mesh` context, or None (constraints no-op)."""
    stack = getattr(_STATE, "stack", None)
    return stack[-1] if stack else None


@contextmanager
def use_mesh(mesh: Mesh, strategy: Optional[str] = None):
    """Activate `shd` constraints for code traced inside the block."""
    ctx = ShardCtx(mesh, strategy)
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(ctx)
    try:
        yield ctx
    finally:
        stack.pop()


# ---------------------------------------------------------------------------
# divisibility filter
# ---------------------------------------------------------------------------
def _fit(entries, shape, mesh: Mesh) -> P:
    """Drop any spec entry whose mesh-axis product does not divide the dim."""
    sizes = _axis_sizes(mesh)
    used = set()
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in sizes and a not in used)
        n = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if not axes or n <= 1 or dim % n != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def _spec_fits(entries, shape, mesh) -> bool:
    fitted = _fit(entries, shape, mesh)
    return tuple(fitted) == tuple(
        e if not (isinstance(e, tuple) and len(e) == 1) else e[0]
        for e in entries)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def shard_map(f, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """Version-portable shard_map: `jax.shard_map(check_vma=)` on new jax,
    `jax.experimental.shard_map(check_rep=)` on 0.4.x."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
# column-parallel (output dim over TP) / row-parallel (input dim over TP)
_COL = {"wq", "wk", "wv", "wg", "wu", "w1", "swg", "swu",
        "wr_t", "wk_t", "wv_t", "wg_t", "wck", "in_proj"}
_ROW = {"wo", "wd", "w2", "swd", "wcv", "out_proj"}
# stacked-subtree markers: leaves below these have a leading layer axis
_STACKED = {"layers", "enc_layers"}


def _path_names(kp) -> list:
    return [str(getattr(k, "key", getattr(k, "idx", k))) for k in kp]


def _leaf_spec(kp, leaf, mesh: Mesh) -> P:
    parts = _path_names(kp)
    name = parts[-1] if parts else ""
    shape = tuple(leaf.shape)
    data = tuple(a for a in mesh.axis_names if a in _DATA_AXES)
    data_entry = data if len(data) > 1 else (data[0] if data else None)
    lead = [None] if any(p in _STACKED for p in parts) else []
    nd = len(shape) - len(lead)

    if name == "embed":                      # (V, d): vocab-parallel
        entries = ["model", data_entry]
    elif name == "head":                     # (d, V): vocab-parallel out
        entries = [data_entry, "model"]
    elif name == "pos_embed":
        entries = [None, data_entry]
    elif nd == 3 and name in ("wg", "wu", "wd") and "moe" in parts:
        entries = lead + ["model", data_entry, None]   # expert-parallel
    elif nd == 2 and name in _COL:
        entries = lead + [data_entry, "model"]
    elif nd == 2 and name in _ROW:
        entries = lead + ["model", data_entry]
    else:                                    # norms, biases, small matrices
        entries = lead + [None] * nd
    return _fit(entries, shape, mesh)


def param_specs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec per leaf of a (possibly abstract) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: _leaf_spec(kp, x, mesh), params)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    """NamedSharding per leaf (for device_put / jit out_shardings)."""
    return to_shardings(param_specs(params, mesh), mesh)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------
def batch_specs(batch: Any, mesh: Mesh, strategy: str = "fsdp") -> Any:
    """Batch-dim-sharded specs for an input tree (tokens/labels/frames/...).

    Both strategies shard dim 0 over the data axes; the filter replicates
    anything that does not divide (e.g. global_batch=1 long-context decode).
    """
    ctx = ShardCtx(mesh, strategy)
    entry = ctx.batch_axes if ctx.batch_axes else None

    def leaf(x):
        shape = tuple(x.shape)
        return _fit([entry] + [None] * (len(shape) - 1), shape, mesh)

    return jax.tree.map(leaf, batch)


def cache_specs(cache: Any, mesh: Mesh) -> Any:
    """KV/state cache specs: batch over data axes, heads over TP.

    k/v/ck/cv are (L, B, S, KH, hd): shard KH over `model`; when KH does not
    divide (GQA models with few KV heads), fall back to sharding S instead
    so the cache still distributes. rwkv state s is (L, B, H, hd, hd).
    """
    ctx = ShardCtx(mesh, None)
    b = ctx.batch_axes if ctx.batch_axes else None

    def leaf(kp, x):
        name = _path_names(kp)[-1] if kp else ""
        shape = tuple(x.shape)
        if name in ("k", "v", "ck", "cv") and len(shape) == 5:
            primary = [None, b, None, "model", None]
            if _spec_fits(primary, shape, mesh):
                return _fit(primary, shape, mesh)
            return _fit([None, b, "model", None, None], shape, mesh)
        if name == "s" and len(shape) == 5:
            return _fit([None, b, "model", None, None], shape, mesh)
        return _fit([None, b] + [None] * (len(shape) - 2), shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf, cache)


# ---------------------------------------------------------------------------
# activation constraints (no-ops outside `use_mesh`)
# ---------------------------------------------------------------------------
def _constrain(x, entries):
    ctx = active()
    if ctx is None or not hasattr(x, "ndim") or x.ndim != len(entries):
        return x
    spec = _fit(entries, x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def act_tokens(x):
    """(B, S) int tokens: batch-sharded."""
    ctx = active()
    if ctx is None:
        return x
    return _constrain(x, [ctx.batch_axes, None])


def act_residual(x):
    """(B, S, d) residual stream: batch + sequence-parallel over TP."""
    ctx = active()
    if ctx is None:
        return x
    return _constrain(x, [ctx.batch_axes, ctx.model_axis, None])


def act_partial_out(x):
    """Pre-residual block output: same layout as the residual so the TP
    reduction lowers as reduce-scatter into the sequence-parallel shard."""
    return act_residual(x)


def act_heads(x):
    """(B, S, H, hd) attention tensors: heads over TP."""
    ctx = active()
    if ctx is None:
        return x
    return _constrain(x, [ctx.batch_axes, None, ctx.model_axis, None])


def act_ce_hidden(x):
    """(B, C, d) CE chunk hidden: batch-sharded, gathered over TP."""
    ctx = active()
    if ctx is None:
        return x
    return _constrain(x, [ctx.batch_axes, None, None])


def act_logits(x):
    """(B, C, V) CE chunk logits: vocab-parallel."""
    ctx = active()
    if ctx is None:
        return x
    return _constrain(x, [ctx.batch_axes, None, ctx.model_axis])


def act_moe_grouped(x):
    """(G, ...) token-grouped MoE tensors: group axis over EVERY mesh axis
    so dispatch/combine scatters stay device-local."""
    ctx = active()
    if ctx is None:
        return x
    return _constrain(x, [ctx.all_axes] + [None] * (x.ndim - 1))


def act_moe_dispatch(x):
    """(G, E, C, d)-style expert-slotted tensors: experts over TP (the
    group-axis reshard on entry/exit is the EP all-to-all)."""
    ctx = active()
    if ctx is None:
        return x
    return _constrain(
        x, [ctx.batch_axes, ctx.model_axis] + [None] * (x.ndim - 2))
