"""Policy-driven mini-batch construction (the paper's contribution as an
API).

    from repro import batching

    pol = batching.make_policy("comm_rand", mix=0.125, p=1.0)
    caps = batching.CapsCalibrator().caps_for(g, pol, 1024, (10, 10, 10))
    stream = batching.BatchStream(g, pol, 1024, (10, 10, 10), caps)
    for minibatch in stream.epoch(): ...       # resumable via stream.cursor

Submodules: `policy` (BatchPolicy protocol + registry), `order` (the one
block-shuffle operator), `calibrate` (cached cap calibration), `stream`
(resumable prefetching `BatchStream` / `eval_batches`). Neighbor
selection is the sibling `repro.sampling` subsystem: each policy binds a
sampler via `sampler_spec()` and the stream threads it — as a static jit
argument — into the compiled batch builder.

`policy` and `order` are numpy-only and import eagerly (configs depend on
them); `stream`/`calibrate` pull in jax + the device builder and load
lazily via PEP 562 so `configs.base -> batching.policy` stays cycle-free.
"""
from repro.batching.order import (block_shuffle, community_groups,   # noqa: F401
                                  make_batches)
from repro.batching.policy import (BatchPolicy, ClusterGCNPolicy,    # noqa: F401
                                   CommRandPolicy, LaborPolicy,
                                   as_policy, available_policies,
                                   make_policy, register, root_batches)

_LAZY = {
    "BatchStream": "repro.batching.stream",
    "Cursor": "repro.batching.stream",
    "eval_batches": "repro.batching.stream",
    "CapsCalibrator": "repro.batching.calibrate",
    "graph_fingerprint": "repro.batching.calibrate",
}

__all__ = [
    "BatchPolicy", "BatchStream", "CapsCalibrator", "ClusterGCNPolicy",
    "CommRandPolicy", "Cursor", "LaborPolicy", "as_policy",
    "available_policies", "block_shuffle", "community_groups",
    "eval_batches", "graph_fingerprint", "make_batches", "make_policy",
    "register", "root_batches",
]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro.batching' has no attribute {name!r}")
