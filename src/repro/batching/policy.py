"""Batch-construction policies: one protocol, one registry.

A `BatchPolicy` decides the (possibly constrained-random) order in which
training roots are visited each epoch, plus the intra-community sampling
weight `p` used by the biased neighbor sampler. Everything that builds
batches — `BatchStream`, caps calibration, baselines, benchmarks — goes
through this interface; the policy names are the paper's knobs:

    rand        uniform random shuffle (baseline)
    norand      static community order (no shuffle)
    comm_rand   block shuffle with the MIX knob (paper §4.1)
    clustergcn  random unions of communities (prior work, §6.3)
    labor       uniform order + LABOR shared-randomness sampling (§6.3)

`CommRandPolicy` (previously in `configs.base`, which keeps a deprecation
shim) is the registered implementation behind the first three names.

A policy also decides HOW neighbors are drawn, via `sampler_spec()`: a
plain `(name, kwargs)` pair into the `repro.sampling` registry (kept as
data so this module stays numpy-only — `repro.sampling.for_policy`
resolves it). The COMM-RAND family and ClusterGCN bind the biased
two-phase sampler at their `p` (`repro.sampling.BiasedTwoPhaseSampler`,
the old hardcoded `core.sampler` path); `labor` binds the device-side
shared-randomness `LaborSampler`, which is what actually shrinks its
footprint — the `p` knob is meaningless to it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.batching import order as order_mod


@runtime_checkable
class BatchPolicy(Protocol):
    """Protocol every registered policy satisfies."""

    p: float        # intra-community edge weight during neighbor sampling

    @property
    def name(self) -> str: ...

    def epoch_order(self, train_ids: np.ndarray, communities: np.ndarray,
                    rng: np.random.Generator) -> np.ndarray:
        """A permutation of `train_ids` for one epoch."""
        ...

    def sampler_spec(self) -> Tuple[str, Dict]:
        """(name, kwargs) into the `repro.sampling` registry: the neighbor
        sampler this policy trains through."""
        ...

    def describe(self) -> str: ...


_REGISTRY: Dict[str, Callable[..., "BatchPolicy"]] = {}


def register(name: str):
    """Register a policy factory under `name` (used by `make_policy`)."""
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def make_policy(name: str, **kwargs) -> "BatchPolicy":
    """Instantiate a registered policy: `make_policy("comm_rand", mix=.125)`."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown policy {name!r}; registered: {available_policies()}")
    return _REGISTRY[name](**kwargs)


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def as_policy(obj) -> "BatchPolicy":
    """Normalize a policy name / policy object to a BatchPolicy."""
    if isinstance(obj, str):
        return make_policy(obj)
    if hasattr(obj, "epoch_order") and hasattr(obj, "p"):
        return obj
    raise TypeError(f"not a batch policy: {obj!r}")


# ---------------------------------------------------------------------------
# COMM-RAND family (paper §4): rand / norand / comm_rand
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CommRandPolicy:
    """Mini-batch construction policy.

    root_mode:
      rand      — uniform random shuffle of the training set (baseline)
      norand    — static, community-ordered (no shuffle)
      comm_rand — block shuffle (communities as blocks + intra-block shuffle)
    mix: fraction of #communities merged into one super-block before
         shuffling (0.0 = MIX-0%, 0.125 = MIX-12.5%, ...). Only for comm_rand.
    p: intra-community edge weight during neighbor sampling; inter gets 1-p.
       0.5 = uniform (baseline), 1.0 = intra-only.
    """
    root_mode: str = "rand"
    mix: float = 0.0
    p: float = 0.5

    @property
    def name(self) -> str:
        return self.root_mode

    def epoch_order(self, train_ids: np.ndarray, communities: np.ndarray,
                    rng: np.random.Generator) -> np.ndarray:
        if self.root_mode == "rand":
            # hash-keyed permutation (one epoch_words draw) — the same
            # closed form the device mirror computes under jit
            return train_ids[order_mod.hash_perm(
                len(train_ids), order_mod.epoch_words(rng))]
        groups = order_mod.community_groups(train_ids, communities)
        if self.root_mode == "norand":
            return np.concatenate(groups)
        if self.root_mode != "comm_rand":
            raise ValueError(self.root_mode)
        return order_mod.block_shuffle(groups, self.mix, rng)

    def sampler_spec(self) -> Tuple[str, Dict]:
        return ("biased", {"p": self.p})

    def describe(self) -> str:
        if self.root_mode == "rand":
            root = "RAND-ROOTS"
        elif self.root_mode == "norand":
            root = "NORAND-ROOTS"
        else:
            root = f"COMM-RAND-MIX-{self.mix * 100:g}%"
        return f"{root} p={self.p:g}"


@register("rand")
def _make_rand(p: float = 0.5, **_kw) -> CommRandPolicy:
    return CommRandPolicy("rand", 0.0, p)


@register("norand")
def _make_norand(p: float = 1.0, **_kw) -> CommRandPolicy:
    return CommRandPolicy("norand", 0.0, p)


@register("comm_rand")
def _make_comm_rand(mix: float = 0.125, p: float = 1.0,
                    **_kw) -> CommRandPolicy:
    return CommRandPolicy("comm_rand", mix, p)


# ---------------------------------------------------------------------------
# prior-work policies (paper §6.3)
# ---------------------------------------------------------------------------
@register("clustergcn")
@dataclass(frozen=True)
class ClusterGCNPolicy:
    """ClusterGCN [14] partition unions: each epoch shuffles the community
    ids and merges consecutive groups of `parts_per_batch` into one batch.
    `member_groups` gives the full induced-node groups the baseline trainer
    consumes; `epoch_order` is the same grouping restricted to train roots.
    """
    parts_per_batch: int = 2
    p: float = 0.5

    @property
    def name(self) -> str:
        return "clustergcn"

    def community_order(self, communities: np.ndarray,
                        rng: np.random.Generator) -> List[np.ndarray]:
        n_comm = int(communities.max()) + 1
        order = order_mod.hash_perm(n_comm, order_mod.epoch_words(rng))
        return np.split(order, range(self.parts_per_batch, n_comm,
                                     self.parts_per_batch))

    @staticmethod
    def _grouped(ids: np.ndarray, comm_of_ids: np.ndarray, n_comm: int,
                 unions: List[np.ndarray]) -> List[np.ndarray]:
        """One bucketed pass: argsort `ids` by community once, then each
        union is a concat of bucket slices (replacing the old O(C·N)
        per-union `np.isin` scan). The position sort restores the original
        `ids` order the masked implementation produced."""
        by_comm = np.argsort(comm_of_ids, kind="stable")
        bounds = np.zeros(n_comm + 1, np.int64)
        np.add.at(bounds, comm_of_ids + 1, 1)
        np.cumsum(bounds, out=bounds)
        out = []
        for union in unions:
            pos = np.concatenate(
                [by_comm[bounds[c]:bounds[c + 1]] for c in union]
                or [np.zeros(0, np.int64)])
            out.append(ids[np.sort(pos)])
        return out

    def member_groups(self, communities: np.ndarray,
                      rng: np.random.Generator) -> List[np.ndarray]:
        """ALL node ids per community union (one epoch of subgraph batches)."""
        n_comm = int(communities.max()) + 1
        return self._grouped(np.arange(len(communities)), communities,
                             n_comm, self.community_order(communities, rng))

    def epoch_order(self, train_ids: np.ndarray, communities: np.ndarray,
                    rng: np.random.Generator) -> np.ndarray:
        n_comm = int(communities.max()) + 1
        return np.concatenate(self._grouped(
            train_ids, communities[train_ids], n_comm,
            self.community_order(communities, rng)))

    def sampler_spec(self) -> Tuple[str, Dict]:
        return ("biased", {"p": self.p})

    def describe(self) -> str:
        # p is part of the description: CapsCalibrator keys its disk cache
        # on describe(), and p changes the sampled-neighborhood footprint
        return f"ClusterGCN({self.parts_per_batch} parts/batch) p={self.p:g}"


@register("labor")
@dataclass(frozen=True)
class LaborPolicy:
    """LABOR-lite [9]: structure-agnostic roots (uniform shuffle); the
    footprint reduction comes from shared per-node hash randomness during
    neighbor sampling — `sampler_spec()` binds the device-side
    `repro.sampling.LaborSampler`, so `make_policy("labor")` trains
    through the same jit-compiled pipeline as every other policy.

    `p` exists only to satisfy the BatchPolicy protocol (uniform-eval
    contract); the LABOR sampler ignores it."""
    p: float = 0.5

    @property
    def name(self) -> str:
        return "labor"

    def epoch_order(self, train_ids: np.ndarray, communities: np.ndarray,
                    rng: np.random.Generator) -> np.ndarray:
        return train_ids[order_mod.hash_perm(
            len(train_ids), order_mod.epoch_words(rng))]

    def sampler_spec(self) -> Tuple[str, Dict]:
        return ("labor", {})

    def describe(self) -> str:
        return "LABOR-lite(shared-randomness)"


# ---------------------------------------------------------------------------
# convenience: one epoch of root-id batches, no device work
# ---------------------------------------------------------------------------
def root_batches(graph, policy, batch_size: int, *, seed: int = 0,
                 epoch: int = 0, drop_last: bool = False) -> np.ndarray:
    """(n_batches, batch_size) root ids for `epoch`, -1-padded. Deterministic
    in (seed, epoch) — the same derivation `BatchStream` uses."""
    rng = np.random.default_rng((seed, epoch))
    order = as_policy(policy).epoch_order(
        graph.train_ids, graph.communities, rng)
    return order_mod.make_batches(order, batch_size, drop_last)
