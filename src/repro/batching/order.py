"""The ONE block-shuffle operator behind every ordering policy.

The paper's COMM-RAND (§4.1) and the LM corpus shuffler are the same
algorithm over different block definitions (graph communities vs corpus
shards): shuffle blocks as wholes, merge consecutive groups of
``max(1, round(mix * n_blocks))`` shuffled blocks into super-blocks, then
shuffle WITHIN each super-block. ``mix=0`` keeps every block contiguous
(maximum locality); ``mix=1`` degenerates to a full uniform shuffle.

`core.partition.epoch_order` and `data.pipeline.BlockShuffler` both
delegate here — previously they carried duplicated copies of this loop.

Randomness is COUNTER-BASED: each epoch draws two uint32 key words from
the caller's Generator (`epoch_words`, the ONLY consumption of Generator
state) and every shuffle decision is a murmur-style hash of those words
with a position counter, resolved by stable argsort. That makes the whole
epoch permutation a closed-form function of `(words, static layout)` —
which is exactly what lets `repro.pipeline.device_order` run the SAME
computation under `jax.jit` on device, bit-matched element for element
(stable argsort over identical uint32 keys is deterministic on both
sides). The previous implementation drew from the Generator inside a
per-block Python loop, which pinned ordering to the host.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

# murmur3-finalizer multipliers (shared with the jnp mirror in
# repro.pipeline.device_order — keep in sync by importing from here)
MIX_A = 0x85EBCA6B
MIX_B = 0xC2B2AE35
# per-stage salts so block-level and element-level decisions are
# independent streams of the same two epoch words
SALT_PERM = 0x9E3779B9        # whole-set permutations (rand / labor roots)
SALT_BLOCK = 0x7F4A7C15       # block-as-a-whole shuffle
SALT_ELEM = 0x94D049BB        # within-super-block shuffle


def epoch_words(rng: np.random.Generator) -> np.ndarray:
    """The one Generator draw per epoch: two uint32 key words. Every
    ordering decision hashes these — so the device mirror only needs the
    words, not the Generator."""
    return rng.integers(0, 2 ** 32, size=2, dtype=np.uint32)


def hash_u32(idx: np.ndarray, words: np.ndarray, salt: int) -> np.ndarray:
    """Murmur-style mix of a position counter with the epoch words ->
    uint32 keys. Pure uint32 wraparound arithmetic; the jnp mirror in
    `repro.pipeline.device_order` is op-for-op identical."""
    x = np.asarray(idx).astype(np.uint32)
    for w in (np.uint32(words[0]) ^ np.uint32(salt), np.uint32(words[1])):
        x = x ^ w
        x = x * np.uint32(MIX_A)
        x = x ^ (x >> np.uint32(13))
        x = x * np.uint32(MIX_B)
        x = x ^ (x >> np.uint32(16))
    return x


def hash_perm(n: int, words: np.ndarray, salt: int = SALT_PERM) -> np.ndarray:
    """Permutation of arange(n): stable argsort of per-position hash keys."""
    return np.argsort(hash_u32(np.arange(n), words, salt), kind="stable")


def block_shuffle_perm(sizes: np.ndarray, mix: float,
                       words: np.ndarray) -> np.ndarray:
    """The block-shuffle as a pure permutation over element positions.

    `sizes[b]` is the length of block b; elements are indexed in
    block-concatenation order (block 0's elements first). Returns `perm`
    such that `concat(blocks)[perm]` is the shuffled epoch order:
    (1) blocks shuffled as wholes by block-level hash keys, (2) merged in
    consecutive groups of ``max(1, round(mix * n_blocks))`` into
    super-blocks, (3) elements shuffled within each super-block by
    element-level hash keys of their post-block-shuffle position.

    Fully vectorized (two stable argsorts); mirrored on device by
    `repro.pipeline.device_order` over the same static layout arrays.
    """
    sizes = np.asarray(sizes, np.int64)
    n = len(sizes)
    total = int(sizes.sum())
    if n == 0 or total == 0:
        return np.zeros(0, np.int64)
    # (1) block-as-a-whole shuffle: rank[b] = position of block b
    border = np.argsort(hash_u32(np.arange(n), words, SALT_BLOCK),
                        kind="stable")
    rank = np.empty(n, np.int64)
    rank[border] = np.arange(n)
    # (2) super-block of a block at shuffled rank r: r // m
    m = max(1, int(round(mix * n)))
    starts_shuf = np.zeros(n, np.int64)
    np.cumsum(sizes[border][:-1], out=starts_shuf[1:])
    # per element: its block, offset within the block, and position in the
    # post-block-shuffle concatenation
    block_of = np.repeat(np.arange(n), sizes)
    block_start = np.zeros(n, np.int64)
    np.cumsum(sizes[:-1], out=block_start[1:])
    off_in_block = np.arange(total) - block_start[block_of]
    elem_rank = rank[block_of]
    gpos = starts_shuf[elem_rank] + off_in_block
    sb = elem_rank // m
    # (3) within-super-block shuffle: stable sort by (super-block, hash of
    # post-shuffle position) — two stable passes == one lexicographic sort
    idx = np.argsort(hash_u32(gpos, words, SALT_ELEM), kind="stable")
    return idx[np.argsort(sb[idx], kind="stable")]


def community_groups(train_ids: np.ndarray,
                     communities: np.ndarray) -> List[np.ndarray]:
    """Training-set node ids grouped per community (ascending comm id)."""
    comm = communities[train_ids]
    order = np.argsort(comm, kind="stable")
    sorted_ids = train_ids[order]
    sorted_comm = comm[order]
    cuts = np.flatnonzero(np.diff(sorted_comm)) + 1
    return np.split(sorted_ids, cuts)


def block_shuffle(blocks: Sequence[np.ndarray], mix: float,
                  rng: np.random.Generator) -> np.ndarray:
    """blocks -> shuffled super-blocks -> intra-shuffled concatenation.

    (1) shuffle blocks as wholes, (2) merge consecutive groups of
    ``max(1, round(mix * len(blocks)))`` into super-blocks, (3) shuffle the
    contents of each super-block. Draws exactly one `epoch_words` pair from
    `rng`, so a fixed seed gives a reproducible epoch order.
    """
    n = len(blocks)
    if n == 0:
        return np.zeros(0, np.int64)
    words = epoch_words(rng)
    flat = np.concatenate(blocks)
    sizes = np.fromiter((len(b) for b in blocks), np.int64, count=n)
    return flat[block_shuffle_perm(sizes, mix, words)]


def make_batches(order: np.ndarray, batch_size: int,
                 drop_last: bool = False) -> np.ndarray:
    """Split an epoch order into (n_batches, batch_size); last batch padded
    with -1 unless drop_last."""
    n = len(order)
    if drop_last:
        n_batches = n // batch_size
        return order[:n_batches * batch_size].reshape(n_batches, batch_size)
    n_batches = (n + batch_size - 1) // batch_size
    out = np.full((n_batches, batch_size), -1, order.dtype)
    out.flat[:n] = order
    return out
