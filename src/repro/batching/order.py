"""The ONE block-shuffle operator behind every ordering policy.

The paper's COMM-RAND (§4.1) and the LM corpus shuffler are the same
algorithm over different block definitions (graph communities vs corpus
shards): shuffle blocks as wholes, merge consecutive groups of
``max(1, round(mix * n_blocks))`` shuffled blocks into super-blocks, then
shuffle WITHIN each super-block. ``mix=0`` keeps every block contiguous
(maximum locality); ``mix=1`` degenerates to a full uniform shuffle.

`core.partition.epoch_order` and `data.pipeline.BlockShuffler` both
delegate here — previously they carried duplicated copies of this loop.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def community_groups(train_ids: np.ndarray,
                     communities: np.ndarray) -> List[np.ndarray]:
    """Training-set node ids grouped per community (ascending comm id)."""
    comm = communities[train_ids]
    order = np.argsort(comm, kind="stable")
    sorted_ids = train_ids[order]
    sorted_comm = comm[order]
    cuts = np.flatnonzero(np.diff(sorted_comm)) + 1
    return np.split(sorted_ids, cuts)


def block_shuffle(blocks: Sequence[np.ndarray], mix: float,
                  rng: np.random.Generator) -> np.ndarray:
    """blocks -> shuffled super-blocks -> intra-shuffled concatenation.

    (1) shuffle blocks as wholes, (2) merge consecutive groups of
    ``max(1, round(mix * len(blocks)))`` into super-blocks, (3) shuffle the
    contents of each super-block. Draws from `rng` in exactly that order,
    so a fixed seed gives a reproducible epoch order.
    """
    n = len(blocks)
    if n == 0:
        return np.zeros(0, np.int64)
    order = rng.permutation(n)
    m = max(1, int(round(mix * n)))
    out = []
    for i in range(0, n, m):
        sb = np.concatenate([blocks[j] for j in order[i:i + m]])
        rng.shuffle(sb)
        out.append(sb)
    return np.concatenate(out)


def make_batches(order: np.ndarray, batch_size: int,
                 drop_last: bool = False) -> np.ndarray:
    """Split an epoch order into (n_batches, batch_size); last batch padded
    with -1 unless drop_last."""
    n = len(order)
    if drop_last:
        n_batches = n // batch_size
        return order[:n_batches * batch_size].reshape(n_batches, batch_size)
    n_batches = (n + batch_size - 1) // batch_size
    out = np.full((n_batches, batch_size), -1, order.dtype)
    out.flat[:n] = order
    return out
