"""Cap calibration with an on-disk cache.

`calibrate_caps` (core.minibatch) probes an epoch with the exact numpy
builder to size the static per-level unique caps — a pure function of
(graph, policy, batch size, fanouts, probe params), but an expensive one on
real graphs. `CapsCalibrator` memoizes it in a JSON file keyed by a graph
fingerprint + the policy knobs, so repeated runs and benchmark sweeps skip
the probe entirely.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro import sampling
from repro.batching.policy import as_policy
from repro.core import minibatch as mb
from repro.graphs.csr import Graph


def graph_fingerprint(graph: Graph) -> str:
    """Cheap content hash: identity + strided samples of the topology,
    communities and split — enough to invalidate on any preprocessing
    change without hashing the full edge list."""
    h = hashlib.sha1()
    h.update(f"{graph.name}|{graph.num_nodes}|{graph.num_edges}|"
             f"{len(graph.train_ids)}".encode())
    for arr in (graph.indptr, graph.indices, graph.communities,
                graph.train_ids):
        if arr is None:
            continue
        a = np.asarray(arr)
        stride = max(1, len(a) // 256)
        h.update(np.ascontiguousarray(a[::stride]).tobytes())
    return h.hexdigest()[:16]


@dataclass
class CapsCalibrator:
    """Wraps `calibrate_caps` with a write-through JSON cache.

    cache_path=None disables the disk cache (every call probes). The cache
    key covers the graph fingerprint, the policy description (root_mode /
    mix / p), the BOUND SAMPLER's description (the sampler is a static jit
    argument, so caps are a per-sampler compile-time property), the batch
    size, the fanouts, and every probe parameter.
    """
    cache_path: Optional[str] = None
    n_probe: int = 6
    margin: float = 1.15
    seed: int = 0
    align: int = 128

    def key(self, graph: Graph, policy, batch_size: int, fanouts) -> str:
        pol = as_policy(policy)
        return "|".join([
            graph_fingerprint(graph), type(pol).__name__, pol.describe(),
            sampling.for_policy(pol).describe(),
            str(batch_size), ",".join(str(f) for f in fanouts),
            f"n{self.n_probe}", f"m{self.margin:g}", f"s{self.seed}",
            f"a{self.align}"])

    def _load(self) -> dict:
        """Read the caps cache, treating ANY corruption as a cache miss:
        a truncated/garbled file (crash mid-write on a non-atomic
        filesystem, bit rot), valid JSON that isn't a dict, binary
        garbage (UnicodeDecodeError is a ValueError) — all discard and
        recalibrate rather than crash. The write side (`_store`) is
        atomic; the read side has to assume the worst anyway."""
        if not self.cache_path or not os.path.exists(self.cache_path):
            return {}
        try:
            with open(self.cache_path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return {}
        return data if isinstance(data, dict) else {}

    def _store(self, cache: dict) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(self.cache_path)),
                    exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(os.path.abspath(self.cache_path)),
            prefix=".caps_", suffix=".json")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(cache, f, indent=1)
            os.replace(tmp, self.cache_path)   # atomic on POSIX
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def caps_for(self, graph: Graph, policy, batch_size: int,
                 fanouts) -> Tuple[int, ...]:
        key = self.key(graph, policy, batch_size, fanouts)
        cache = self._load()
        if key in cache:
            try:
                caps = tuple(int(c) for c in cache[key])
                if len(caps) == len(tuple(fanouts)) and \
                        all(c > 0 for c in caps):
                    return caps
            except (TypeError, ValueError):
                pass                   # corrupt entry: fall through, reprobe
        caps = mb.calibrate_caps(
            graph, as_policy(policy), batch_size, tuple(fanouts),
            n_probe=self.n_probe, margin=self.margin, seed=self.seed,
            align=self.align)
        if self.cache_path:
            cache = self._load()               # re-read: last writer merges
            cache[key] = list(caps)
            self._store(cache)
        return caps
