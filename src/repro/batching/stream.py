"""Resumable streams of compiled `MiniBatch`es.

`BatchStream` is the single entry point for GNN batch construction: it owns
the per-epoch root ordering (via a `BatchPolicy`), the jit-compiled static
batch builder, and an explicit `Cursor(epoch, pos)` that goes into every
checkpoint — the same resume contract `LMStream` has for the LM corpus.

Determinism contract: everything is derived from `(seed, epoch, pos)` —
the numpy epoch order from `default_rng((seed, epoch))`, the device
sampling key from `fold_in(fold_in(key(seed), epoch), pos)`. A stream
restored mid-epoch from a cursor therefore reproduces the continuation
bit-exactly, with no RNG state in the checkpoint beyond the cursor itself.
Shared-randomness samplers (LABOR) additionally receive the EPOCH-level
key `fold_in(key(seed), epoch)`, also a pure function of the cursor.

Neighbor sampling is pluggable: the stream resolves the policy's
`sampler_spec()` through `repro.sampling` (override with `sampler=`), and
the sampler rides into the jit-compiled builder as a static argument.

Prefetch: while the consumer runs step i, the builder for batch i+1 has
already been dispatched (jit dispatch is async), overlapping host batch
assembly + host->device transfer with device compute.

Feature cache: `cache=` attaches a `repro.featcache` cache (a static
`CachePlan`, a dynamic CLOCK `DynamicCacheState`, an admission-policy
name, or `"dynamic[:admission]"` — normalized by `featcache.as_cache`
against this stream's policy/shape) to the stream; consumers route
layer-0 feature reads through it (`gather_cached`) and measure hit
rates. A dynamic cache is MUTABLE trainer state: `GNNTrainer` re-assigns
`stream.cache` as the state evolves, so the stream always carries the
current residency.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sampling
from repro.batching.order import make_batches
from repro.batching.policy import BatchPolicy, as_policy
from repro.core import minibatch as mb
from repro.graphs.csr import DeviceGraph, Graph


@dataclass
class Cursor:
    """Stream position: epoch number + batch index within the epoch."""
    epoch: int = 0
    pos: int = 0

    def state(self) -> dict:
        return {"epoch": self.epoch, "pos": self.pos}

    @staticmethod
    def from_state(d) -> "Cursor":
        return Cursor(int(d["epoch"]), int(d["pos"]))


class BatchStream:
    """Policy-driven, cursor-resumable stream of compiled `MiniBatch`es."""

    def __init__(self, graph: Graph, policy, batch_size: int, fanouts,
                 caps, *, seed: int = 0, cursor: Optional[Cursor] = None,
                 drop_last: bool = False, sampler=None,
                 mode: str = "sample",
                 device_graph: Optional[DeviceGraph] = None,
                 labels: Optional[jnp.ndarray] = None,
                 dispatch_ahead: bool = True, cache=None,
                 prefetch=None):
        self.graph = graph
        self.policy: BatchPolicy = as_policy(policy)
        self.batch_size = batch_size
        self.fanouts = tuple(fanouts)
        self.caps = tuple(caps)
        self.seed = seed
        self.cursor = cursor or Cursor()
        self.drop_last = drop_last
        # sampler=None binds the policy's own sampler_spec(); mode="all" is
        # the deprecated string knob for the full-neighborhood sampler
        self.sampler = sampling.resolve(
            sampler, mode, lambda: sampling.for_policy(self.policy))
        # the device feature cache riding with the stream: any
        # `featcache.as_cache` spec (static plan, dynamic CLOCK state, or
        # name, built here against this stream's policy/shape) that
        # consumers gather layer-0 features through — `GNNTrainer` reads
        # it back off the stream and keeps it current as dynamic
        # admission evolves the state
        self.cache = None
        if cache is not None:
            from repro import featcache
            self.cache = featcache.as_cache(
                cache, graph, policy=self.policy, batch_size=batch_size,
                fanouts=self.fanouts, seed=seed)
        if prefetch is not None:
            # the old name oversold a single-slot async DISPATCH as
            # prefetching — real depth-k prefetch on a background thread
            # is `repro.pipeline.AsyncBatchStream`
            warnings.warn(
                "BatchStream(prefetch=...) is deprecated: the flag only "
                "controls single-slot async dispatch and is now named "
                "dispatch_ahead=; for actual background prefetching use "
                "repro.pipeline.AsyncBatchStream", DeprecationWarning,
                stacklevel=2)
            dispatch_ahead = prefetch
        self.dispatch_ahead = dispatch_ahead
        self.g = device_graph or DeviceGraph.from_graph(graph)
        self.labels = labels if labels is not None \
            else jnp.asarray(graph.labels)
        self._order_cache = (-1, None)        # (epoch, (n_batches, B) roots)
        self._epoch_ctx = (-1, None)          # (epoch, shared sampler state)
        self._prefetched = None               # (epoch, pos, MiniBatch)

    # -- deterministic derivations ------------------------------------------
    def root_batches(self, epoch: int) -> np.ndarray:
        """Root-id batches for `epoch` (cached for the current epoch)."""
        if self._order_cache[0] != epoch:
            rng = np.random.default_rng((self.seed, epoch))
            order = self.policy.epoch_order(
                self.graph.train_ids, self.graph.communities, rng)
            self._order_cache = (epoch, make_batches(
                order, self.batch_size, self.drop_last))
        return self._order_cache[1]

    def num_batches(self, epoch: int = None) -> int:
        # closed form (every epoch visits the full train set), so async
        # consumers can size an epoch without materializing its order
        n = len(self.graph.train_ids)
        return n // self.batch_size if self.drop_last \
            else -(-n // self.batch_size)

    def epoch_key(self, epoch: int):
        """Epoch-level PRNG key — what shared-randomness samplers (LABOR)
        draw from, so picks repeat across the epoch's batches and hops."""
        return jax.random.fold_in(jax.random.key(self.seed), epoch)

    def batch_key(self, epoch: int, pos: int):
        """PRNG key for batch (epoch, pos) — pure function of the cursor."""
        return jax.random.fold_in(self.epoch_key(epoch), pos)

    def epoch_ctx(self, epoch: int):
        """Per-epoch shared sampler state (LABOR's node ranks), computed
        ONCE per epoch and threaded into every build — previously the
        ranks were re-hashed inside every batch build."""
        if self._epoch_ctx[0] != epoch:
            self._epoch_ctx = (epoch, mb.sampler_epoch_ctx(
                self.sampler, self.epoch_key(epoch), self.g))
        return self._epoch_ctx[1]

    def build(self, roots: np.ndarray, epoch: int, pos: int) -> mb.MiniBatch:
        """Compile/dispatch the static-shape batch for these roots."""
        return mb._build_batch(
            self.batch_key(epoch, pos), self.epoch_key(epoch), self.g,
            jnp.asarray(roots, jnp.int32), self.labels, self.fanouts,
            self.caps, self.sampler, self.epoch_ctx(epoch))

    # -- iteration -----------------------------------------------------------
    def _take(self, epoch: int, pos: int) -> mb.MiniBatch:
        """Produce batch (epoch, pos) — the override point for async
        streams. The base class consumes its single dispatched-ahead slot
        or builds synchronously from the numpy epoch order."""
        if self._prefetched is not None and \
                self._prefetched[:2] == (epoch, pos):
            batch = self._prefetched[2]
            self._prefetched = None
            return batch
        return self.build(self.root_batches(epoch)[pos], epoch, pos)

    def _dispatch_ahead(self, epoch: int, pos: int) -> None:
        """Fire off batch (epoch, pos) so it overlaps the consumer's
        current step (async jit dispatch; no-op in async streams, which
        have a real queue)."""
        if self.dispatch_ahead:
            self._prefetched = (epoch, pos,
                                self.build(self.root_batches(epoch)[pos],
                                           epoch, pos))

    def epoch(self) -> Iterator[mb.MiniBatch]:
        """Yield the REMAINDER of the current epoch (all of it when the
        cursor sits at pos 0), then advance the cursor to the next epoch.
        After each yield the cursor already points at the next batch, so a
        checkpoint taken mid-iteration resumes after the consumed batch."""
        nb = self.num_batches(self.cursor.epoch)
        if nb and self.cursor.pos >= nb:
            # a consumer stopped exactly on the epoch boundary: normalize
            self.cursor.epoch += 1
            self.cursor.pos = 0
            self._prefetched = None
            nb = self.num_batches(self.cursor.epoch)
        if nb == 0:
            # empty train set, or drop_last with fewer roots than a batch —
            # raising beats __iter__ spinning forever on empty epochs
            raise ValueError(
                f"epoch {self.cursor.epoch} has no batches "
                f"({len(self.graph.train_ids)} train ids, batch_size="
                f"{self.batch_size}, drop_last={self.drop_last})")
        e = self.cursor.epoch
        while self.cursor.epoch == e and self.cursor.pos < nb:
            pos = self.cursor.pos
            batch = self._take(e, pos)
            self.cursor.pos += 1
            if self.cursor.pos < nb:
                self._dispatch_ahead(e, self.cursor.pos)
            yield batch
        if self.cursor.epoch == e:            # exhausted, not broken out of
            self.cursor.epoch += 1
            self.cursor.pos = 0
            self._prefetched = None

    def __iter__(self) -> Iterator[mb.MiniBatch]:
        while True:
            yield from self.epoch()


def eval_batches(graph: Graph, ids: np.ndarray, batch_size: int, fanouts,
                 caps, p: float = 0.5, *, seed: int = 0,
                 sampler=None, mode: str = "sample",
                 device_graph: Optional[DeviceGraph] = None,
                 labels: Optional[jnp.ndarray] = None
                 ) -> Iterator[mb.MiniBatch]:
    """Deterministic sequential batches over `ids` (padded with -1), with
    one-batch prefetch. Keys derive from (seed, chunk index) only, so
    evaluation never perturbs training RNG state. `sampler=None` keeps the
    biased two-phase draw at `p` (the uniform-eval contract); `mode="all"`
    is the deprecated knob for the full-neighborhood sampler."""
    g = device_graph or DeviceGraph.from_graph(graph)
    labels = labels if labels is not None else jnp.asarray(graph.labels)
    fanouts, caps = tuple(fanouts), tuple(caps)
    sampler = sampling.resolve(
        sampler, mode, lambda: sampling.BiasedTwoPhaseSampler(p=float(p)))
    key = jax.random.key(seed)
    chunks = []
    for i in range(0, len(ids), batch_size):
        pad = np.full(batch_size, -1, np.int64)
        chunk = ids[i:i + batch_size]
        pad[:len(chunk)] = chunk
        chunks.append(pad)

    def build(j):
        return mb.build_batch(
            jax.random.fold_in(key, j), g, jnp.asarray(chunks[j], jnp.int32),
            labels, fanouts, caps, sampler, epoch_key=key)

    nxt = build(0) if chunks else None
    for j in range(len(chunks)):
        cur, nxt = nxt, (build(j + 1) if j + 1 < len(chunks) else None)
        yield cur
