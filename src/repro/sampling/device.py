"""The four registered neighbor samplers (device path + numpy mirrors).

All of them share the static-shape contract of the old
`core.sampler.sample_neighbors`: (M,) nodes in (sentinel `num_nodes` for
padding), (M, fanout) int32 sources + bool mask out, self-loop for
isolated nodes, sentinel-propagating for padded rows.

`BiasedTwoPhaseSampler` is the old code moved verbatim (same key splits,
same draws — bit-exact with the deprecated `core.sampler` entry point).
`LaborSampler` is the device-side LABOR path [9]: every candidate
neighbor gets a rank from a hash of (epoch key, source node id), and each
destination keeps its `fanout` lowest-ranked neighbors — so overlapping
neighborhoods select IDENTICAL neighbors and the batch builder's dedup
actually collapses them, with no community information at all.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import ClassVar, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.sampling.base import register_sampler


def _row_meta(g, nodes):
    """Shared per-row lookups; `safe` clamps padded rows to node 0."""
    valid = nodes < g.num_nodes
    safe = jnp.where(valid, nodes, 0)
    return valid, safe, g.indptr[safe], g.degrees[safe]


def _finish(g, valid, safe, deg, src, fanout):
    """Isolated nodes aggregate themselves; padded rows propagate the
    sentinel — identical to the old sampler's tail."""
    src = jnp.where(deg[:, None] > 0, src, safe[:, None])
    src = jnp.where(valid[:, None], src, g.num_nodes)
    mask = jnp.broadcast_to((valid & (deg > 0))[:, None], src.shape)
    return src.astype(jnp.int32), mask


@register_sampler("biased")
@dataclass(frozen=True)
class BiasedTwoPhaseSampler:
    """Paper §4.2 (Figure 4): intra-community edges drawn with unnormalized
    weight `p`, inter with `1-p`. Thanks to the intra-first CSR row layout
    (`n_intra[u]` split point) a draw is two-phase — pick the class with
    prob p*n_intra / (p*n_intra + (1-p)*n_inter), then uniform within the
    class — O(1) per sample, no |E|-sized weight array. With replacement
    within the class (DESIGN.md §7). p=0.5 is uniform over neighbors."""

    p: float = 0.5
    shared_randomness: ClassVar[bool] = False

    @property
    def name(self) -> str:
        return "biased"

    @functools.partial(jax.jit, static_argnames=("self", "fanout"))
    def sample(self, key, g, nodes, fanout: int):
        M = nodes.shape[0]
        valid, safe, start, deg = _row_meta(g, nodes)
        ni = g.n_intra[safe]
        no = deg - ni

        k1, k2, k3 = jax.random.split(key, 3)
        w_i = self.p * ni.astype(jnp.float32)
        w_o = (1.0 - self.p) * no.astype(jnp.float32)
        p_intra = jnp.where(w_i + w_o > 0,
                            w_i / jnp.maximum(w_i + w_o, 1e-9), 0.0)
        p_intra = jnp.where(no == 0, 1.0,
                            jnp.where(ni == 0, 0.0, p_intra))

        u_class = jax.random.uniform(k1, (M, fanout))
        intra = u_class < p_intra[:, None]
        u_off = jax.random.uniform(k2, (M, fanout))
        off_i = jnp.floor(u_off * ni[:, None]).astype(jnp.int32)
        off_o = ni[:, None] + jnp.floor(u_off * no[:, None]).astype(jnp.int32)
        offset = jnp.where(intra, off_i, off_o)
        offset = jnp.clip(offset, 0, jnp.maximum(deg - 1, 0)[:, None])
        src = g.indices[start[:, None] + offset]
        return _finish(g, valid, safe, deg, src, fanout)

    def sample_level_np(self, rng, graph, level, fanout: int,
                        ctx: dict) -> List:
        comm = graph.communities
        srcs = []
        for u in level:
            s, e = graph.indptr[u], graph.indptr[u + 1]
            nbrs = graph.indices[s:e]
            if len(nbrs) == 0:
                srcs.append(np.array([u] * fanout))
                continue
            intra = comm[nbrs] == comm[u]
            ni, no = int(intra.sum()), int((~intra).sum())
            w_i, w_o = self.p * ni, (1 - self.p) * no
            pi = 1.0 if no == 0 else (0.0 if ni == 0 else w_i / (w_i + w_o))
            cls = rng.random(fanout) < pi
            nbr_i = nbrs[intra] if ni else nbrs
            nbr_o = nbrs[~intra] if no else nbrs
            pick = np.where(cls,
                            nbr_i[rng.integers(0, max(ni, 1), fanout)],
                            nbr_o[rng.integers(0, max(no, 1), fanout)])
            srcs.append(pick)
        return srcs

    def describe(self) -> str:
        return f"biased-two-phase(p={self.p:g})"


@register_sampler("uniform")
@dataclass(frozen=True)
class UniformSampler:
    """Uniform with-replacement draw over the whole adjacency row — the
    classic GraphSAGE sampler, with no community bias and a single uniform
    per slot (distributionally equal to `biased` at p=0.5)."""

    shared_randomness: ClassVar[bool] = False

    @property
    def name(self) -> str:
        return "uniform"

    @functools.partial(jax.jit, static_argnames=("self", "fanout"))
    def sample(self, key, g, nodes, fanout: int):
        M = nodes.shape[0]
        valid, safe, start, deg = _row_meta(g, nodes)
        u = jax.random.uniform(key, (M, fanout))
        offset = jnp.floor(u * deg[:, None]).astype(jnp.int32)
        offset = jnp.clip(offset, 0, jnp.maximum(deg - 1, 0)[:, None])
        src = g.indices[start[:, None] + offset]
        return _finish(g, valid, safe, deg, src, fanout)

    def sample_level_np(self, rng, graph, level, fanout: int,
                        ctx: dict) -> List:
        srcs = []
        for u in level:
            nbrs = graph.indices[graph.indptr[u]:graph.indptr[u + 1]]
            if len(nbrs) == 0:
                srcs.append(np.array([u] * fanout))
                continue
            srcs.append(nbrs[rng.integers(0, len(nbrs), fanout)])
        return srcs

    def describe(self) -> str:
        return "uniform"


@register_sampler("full")
@dataclass(frozen=True)
class FullNeighborhoodSampler:
    """Deterministic enumeration of the first `fanout` neighbors (fanout >=
    max degree gives exact full-neighborhood aggregation — the equivalence
    tests' oracle). Retires the old `mode="all"` string knob."""

    shared_randomness: ClassVar[bool] = False

    @property
    def name(self) -> str:
        return "full"

    @functools.partial(jax.jit, static_argnames=("self", "fanout"))
    def sample(self, key, g, nodes, fanout: int):
        N = g.num_nodes
        M = nodes.shape[0]
        valid, safe, start, deg = _row_meta(g, nodes)
        j = jnp.broadcast_to(jnp.arange(fanout), (M, fanout))
        mask = (j < deg[:, None]) & valid[:, None]
        offset = jnp.minimum(j, jnp.maximum(deg - 1, 0)[:, None])
        src = g.indices[start[:, None] + offset]
        src = jnp.where(mask, src,
                        jnp.where(valid[:, None], safe[:, None], N))
        return src.astype(jnp.int32), mask

    def sample_level_np(self, rng, graph, level, fanout: int,
                        ctx: dict) -> List:
        return [graph.indices[graph.indptr[u]:graph.indptr[u + 1]][:fanout]
                for u in level]

    def describe(self) -> str:
        return "full-neighborhood"


def _hash_rank01(key, ids):
    """Shared LABOR randomness: a murmur3-finalizer-style mix of each
    candidate node id with the epoch key's raw words -> float32 in [0, 1).
    Depends ONLY on (key, id): the same source node gets the same rank in
    every row, batch, and hop of an epoch."""
    x = ids.astype(jnp.uint32)
    for w in jax.random.key_data(key).ravel().astype(jnp.uint32):
        x = x ^ w
        x = x * jnp.uint32(0x85EBCA6B)
        x = x ^ (x >> 13)
        x = x * jnp.uint32(0xC2B2AE35)
        x = x ^ (x >> 16)
    return x.astype(jnp.float32) * jnp.float32(2.0 ** -32)


@register_sampler("labor")
@dataclass(frozen=True)
class LaborSampler:
    """Device-side LABOR-lite [9] (Balın et al.): every candidate neighbor
    t gets rank = hash(epoch key, t); each destination keeps its `fanout`
    LOWEST-ranked neighbors (without replacement). Because ranks are a
    pure function of the candidate id and the epoch key, destinations with
    overlapping neighborhoods pick the shared low-rank candidates — the
    unique-node footprint collapses under dedup with zero community info,
    and the picks repeat across hops and batches within an epoch (new key
    -> fresh ranks each epoch).

    The rank gather materializes an (M, max_degree) tile, so per-draw cost
    is O(max_degree) rather than the biased sampler's O(1) — LABOR trades
    sampling FLOPs for feature-gather bytes, which is the paper's bound.
    """

    shared_randomness: ClassVar[bool] = True

    @property
    def name(self) -> str:
        return "labor"

    def epoch_ctx(self, key, g):
        """The per-epoch shared state: every node's rank under the epoch
        key. A pure function of (key, g.num_nodes) — `sample` recomputes
        it when not given one, so hoisting it to once per epoch (the
        batch builder / `repro.pipeline` do) changes no pick."""
        return _hash_rank01(key, jnp.arange(g.num_nodes, dtype=jnp.int32))

    @functools.partial(jax.jit, static_argnames=("self", "fanout"))
    def sample(self, key, g, nodes, fanout: int, ranks=None):
        if g.max_degree == 0 and g.indices.shape[0] > 0:
            raise ValueError(
                "DeviceGraph.max_degree is unset; rebuild the device graph "
                "with DeviceGraph.from_graph for the LABOR sampler")
        M = nodes.shape[0]
        # analysis: allow[no-host-sync-in-hot-path] -- g.max_degree is static Python metadata on DeviceGraph (trace-time branch above), not a traced array
        D = max(int(g.max_degree), fanout, 1)
        valid, safe, start, deg = _row_meta(g, nodes)
        j = jnp.arange(D)
        in_row = j[None, :] < deg[:, None]
        offset = jnp.minimum(j[None, :], jnp.maximum(deg - 1, 0)[:, None])
        cand = g.indices[start[:, None] + offset]          # (M, D)
        # hash each of the N node ids once, then gather: N ops instead of
        # re-mixing every element of the (M, D) candidate tile; callers
        # that build many batches per epoch pass the hoisted `ranks`
        rank_all = self.epoch_ctx(key, g) if ranks is None else ranks
        rank = jnp.where(in_row, rank_all[cand], jnp.inf)
        _, top = jax.lax.top_k(-rank, fanout)              # k smallest ranks
        src = jnp.take_along_axis(cand, top, axis=1)
        keep = jnp.arange(fanout)[None, :] < \
            jnp.minimum(deg, fanout)[:, None]
        mask = keep & valid[:, None]
        src = jnp.where(mask, src,
                        jnp.where(valid[:, None], safe[:, None],
                                  g.num_nodes))
        return src.astype(jnp.int32), mask

    @staticmethod
    def epoch_ranks_np(key, num_nodes: int) -> np.ndarray:
        """Numpy mirror of `epoch_ctx`: identical uint32 mixing of
        arange(num_nodes) with the epoch key's raw words, identical
        uint32->float32 rounding — bit-for-bit equal to the device ranks
        (asserted in tests/test_batch_pipeline.py)."""
        x = np.arange(num_nodes, dtype=np.uint32)
        for w in np.asarray(jax.random.key_data(key)).ravel().astype(
                np.uint32):
            x = x ^ np.uint32(w)
            x = x * np.uint32(0x85EBCA6B)
            x = x ^ (x >> np.uint32(13))
            x = x * np.uint32(0xC2B2AE35)
            x = x ^ (x >> np.uint32(16))
        return x.astype(np.float32) * np.float32(2.0 ** -32)

    def sample_level_np(self, rng, graph, level, fanout: int,
                        ctx: dict) -> List:
        rank = ctx.get("labor_rank")
        if rank is None:                    # one shared draw per epoch
            ek = ctx.get("epoch_key")
            rank = ctx["labor_rank"] = (
                self.epoch_ranks_np(ek, graph.num_nodes)
                if ek is not None else rng.random(graph.num_nodes))
        srcs = []
        for u in level:
            nbrs = graph.indices[graph.indptr[u]:graph.indptr[u + 1]]
            if len(nbrs) == 0:
                continue
            if len(nbrs) > fanout:
                nbrs = nbrs[np.argpartition(rank[nbrs], fanout)[:fanout]]
            srcs.append(nbrs)
        return srcs

    def describe(self) -> str:
        return "labor(shared-hash-topk)"
