"""NeighborSampler protocol + registry.

The paper's §6.3 comparison is really a comparison of *how neighbors are
drawn*: the biased two-phase draw behind COMM-RAND (§4.2), plain uniform
sampling, full-neighborhood enumeration, and LABOR's shared per-node
randomness [9]. `repro.sampling` makes that axis a first-class pluggable
API, the way `repro.batching.policy` made root ordering one.

A sampler is a frozen (hashable) dataclass so it can ride through
`jax.jit` as a STATIC argument — `core.minibatch.build_batch` specializes
the compiled batch builder per sampler, and `CapsCalibrator` keys its disk
cache on `describe()` so each sampler gets its own calibrated caps.

Registered names:

    biased    two-phase intra/inter draw, weight `p` (paper §4.2; default)
    uniform   one uniform draw over the whole adjacency row
    full      deterministic enumeration (retires the old `mode="all"` knob)
    labor     shared-randomness top-k by hash(epoch key, source node id)

Policies bind samplers through `BatchPolicy.sampler_spec()`, which returns
a plain `(name, kwargs)` pair (no import cycle); `for_policy` resolves it.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Protocol, Tuple, runtime_checkable


@runtime_checkable
class NeighborSampler(Protocol):
    """Protocol every registered sampler satisfies.

    `sample` is the device path (jit-traceable, static `self`/`fanout`);
    `sample_level_np` is the exact numpy mirror used by cap calibration,
    the cache simulator, and tests. `shared_randomness` tells the batch
    builder to hand the sampler the EPOCH-level key (same across batches
    and hops) instead of a per-(batch, hop) key.
    """

    shared_randomness: bool

    @property
    def name(self) -> str: ...

    def sample(self, key, g, nodes, fanout: int):
        """nodes: (M,) int32, sentinel `g.num_nodes` for padding.
        Returns (srcs (M, fanout) int32, mask (M, fanout) bool)."""
        ...

    def sample_level_np(self, rng, graph, level, fanout: int,
                        ctx: dict) -> List:
        """Numpy mirror: list of picked-neighbor arrays for `level` nodes.
        `ctx` is a per-epoch dict for shared state (LABOR's ranks)."""
        ...

    def describe(self) -> str: ...


_REGISTRY: Dict[str, Callable[..., "NeighborSampler"]] = {}


def register_sampler(name: str):
    """Register a sampler factory under `name` (used by `make_sampler`)."""
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def make_sampler(name: str, **kwargs) -> "NeighborSampler":
    """Instantiate a registered sampler: `make_sampler("biased", p=1.0)`."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown sampler {name!r}; registered: {available_samplers()}")
    return _REGISTRY[name](**kwargs)


def available_samplers() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def as_sampler(obj) -> "NeighborSampler":
    """Normalize a sampler name / (name, kwargs) spec / instance."""
    if isinstance(obj, str):
        return make_sampler(obj)
    if isinstance(obj, (tuple, list)) and len(obj) == 2 \
            and isinstance(obj[0], str):
        return make_sampler(obj[0], **dict(obj[1]))
    if hasattr(obj, "sample") and hasattr(obj, "shared_randomness"):
        return obj
    raise TypeError(f"not a neighbor sampler: {obj!r}")


def resolve(sampler, mode: str = "sample", fallback=None) -> "NeighborSampler":
    """THE precedence rule for every entry point (`build_batch`,
    `BatchStream`, `eval_batches`): an explicit sampler wins; a bare
    number is the legacy float-p signature (biased draw, or full
    enumeration under the deprecated `mode="all"`); otherwise `mode="all"`
    itself; otherwise `fallback` (a sampler or zero-arg factory)."""
    import numpy as np
    from repro.sampling import device  # registers the built-in samplers

    if sampler is not None:
        if isinstance(sampler, bool):
            raise TypeError(f"not a neighbor sampler: {sampler!r}")
        if isinstance(sampler, (int, float, np.floating)) or (
                hasattr(sampler, "ndim") and getattr(sampler, "ndim") == 0):
            if mode == "all":
                return device.FullNeighborhoodSampler()
            return device.BiasedTwoPhaseSampler(p=float(sampler))
        return as_sampler(sampler)
    if mode == "all":
        return device.FullNeighborhoodSampler()
    return fallback() if callable(fallback) else as_sampler(fallback)


def for_policy(policy) -> "NeighborSampler":
    """The sampler a `BatchPolicy` binds: its `sampler_spec()` if it has
    one, else the biased two-phase draw at the policy's `p` (the behavior
    every policy had before samplers were pluggable)."""
    spec = getattr(policy, "sampler_spec", None)
    if callable(spec):
        return as_sampler(spec())
    p = getattr(policy, "p", None)
    if p is not None:
        return make_sampler("biased", p=float(p))
    raise TypeError(f"cannot derive a sampler from policy {policy!r}")
