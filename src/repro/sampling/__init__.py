"""Pluggable neighbor sampling (paper §4.2 / §6.3 as an API).

    from repro import sampling

    s = sampling.make_sampler("labor")          # or "biased"/"uniform"/"full"
    srcs, mask = s.sample(epoch_key, device_graph, nodes, fanout=10)

Samplers are frozen dataclasses — hashable, so `core.minibatch.build_batch`
takes them as STATIC jit arguments and compiles one batch builder per
sampler. `for_policy` resolves a `BatchPolicy.sampler_spec()` to the
sampler the policy binds (every policy defaults to the biased two-phase
draw at its `p`; `make_policy("labor")` binds the shared-randomness
`LaborSampler`). The old `core.sampler.sample_neighbors` entry point is a
deprecated shim over `BiasedTwoPhaseSampler` / `FullNeighborhoodSampler`.
"""
from repro.sampling.base import (NeighborSampler, as_sampler,   # noqa: F401
                                 available_samplers, for_policy,
                                 make_sampler, register_sampler, resolve)
from repro.sampling.device import (BiasedTwoPhaseSampler,       # noqa: F401
                                   FullNeighborhoodSampler, LaborSampler,
                                   UniformSampler)

__all__ = [
    "BiasedTwoPhaseSampler", "FullNeighborhoodSampler", "LaborSampler",
    "NeighborSampler", "UniformSampler", "as_sampler",
    "available_samplers", "for_policy", "make_sampler", "register_sampler",
    "resolve",
]
