"""`python -m repro.analysis` — run the lint + jaxpr-audit gate.

    python -m repro.analysis                  # report only, exit 0
    python -m repro.analysis --strict         # CI gate: exit 1 on any
                                              #  unwaived violation,
                                              #  unjustified waiver, or
                                              #  failed jaxpr contract
    python -m repro.analysis --skip-jaxpr     # lint only (fast)
    python -m repro.analysis --json out.json  # report path (default
                                              #  BENCH_analysis.json)

Scoping config comes from `[tool.repro_analysis]` in pyproject.toml
when present (found by walking up from the package source), else the
defaults in `analysis/config.py` — the two are kept in sync so local
runs and CI agree.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.config import AnalysisConfig
from repro.analysis.lint import lint_paths


def _src_root() -> Path:
    import repro
    # repro is a namespace package (__file__ is None): locate via __path__
    return Path(list(repro.__path__)[0]).resolve().parent


def _load_config(src_root: Path) -> AnalysisConfig:
    for parent in (src_root, *src_root.parents):
        pyproject = parent / "pyproject.toml"
        if pyproject.is_file():
            try:
                import tomllib
            except ModuleNotFoundError:     # py<3.11: fall back to defaults
                return AnalysisConfig()
            with open(pyproject, "rb") as f:
                return AnalysisConfig.from_pyproject(tomllib.load(f))
    return AnalysisConfig()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-analysis",
        description="determinism & jit-hygiene gate (AST lint + jaxpr "
                    "contract audit)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on unwaived violations, "
                         "unjustified waivers, or failed jaxpr contracts")
    ap.add_argument("--skip-jaxpr", action="store_true",
                    help="run only the AST lint (no tracing)")
    ap.add_argument("--json", default="BENCH_analysis.json",
                    help="report path (default: %(default)s)")
    ap.add_argument("--src", default=None,
                    help="source root to lint (default: the installed "
                         "repro package's src directory)")
    args = ap.parse_args(argv)

    src_root = Path(args.src) if args.src else _src_root()
    config = _load_config(src_root)
    lint = lint_paths(src_root, config)
    report = {"lint": lint.to_json()}

    print(f"lint: {lint.files_checked} files, "
          f"{len(lint.unwaived)} unwaived violation(s), "
          f"{len(lint.waived)} audited waiver(s)")
    for v in lint.unwaived:
        print(f"  {v.path}:{v.line}:{v.col} [{v.rule}] {v.message}")
    for v in lint.unjustified():
        print(f"  {v.path}:{v.line} [{v.rule}] waiver has NO justification")
    for u in lint.unknown_waivers:
        print(f"  {u['path']}:{u['line']} waiver names unknown rule "
              f"{u['rule']!r}")

    jaxpr_ok = True
    if not args.skip_jaxpr:
        from repro.analysis.jaxpr_audit import audit_all
        audit = audit_all()
        report["jaxpr"] = audit
        jaxpr_ok = bool(audit["ok"])
        for section in ("donation", "kernels", "device_order",
                        "fused_build", "train_step", "sharded_step"):
            print(f"jaxpr: {section:12s} "
                  f"{'ok' if audit[section]['ok'] else 'FAIL'}")

    strict_ok = lint.strict_ok() and not lint.unknown_waivers and jaxpr_ok
    report["strict_ok"] = strict_ok
    from repro.obs.metrics import run_metadata
    report["_meta"] = run_metadata()    # shared artifact header (repro.obs)
    out = Path(args.json)
    out.write_text(json.dumps(report, indent=1, default=str) + "\n")
    print(f"report -> {out}  (strict {'PASS' if strict_ok else 'FAIL'})")

    return 0 if (strict_ok or not args.strict) else 1


if __name__ == "__main__":
    sys.exit(main())
