"""Jaxpr contract auditor: static checks over the real jitted artifacts.

Where `lint.py` reads source, this pass reads what JAX will actually
run: it traces the guarded train step, the fused device batch builder,
the device epoch-order programs and the Pallas kernels, then walks the
jaxprs (recursively, through pjit/custom_vjp sub-jaxprs) and asserts

  * no callback primitives (`pure_callback`/`io_callback`/...): a
    callback inside the step is a hidden host round-trip per dispatch;
  * no `convert_element_type` to float64 and no f64 intermediate
    anywhere — the stack is f32/int32 end to end;
  * declared Pallas paths really contain `pallas_call`, and the fused
    gather kernels never fall back to an XLA `gather` on a
    feature-shaped (rows, F) float operand — the materialized gather is
    exactly what the kernels exist to avoid;
  * donated buffers are actually aliased in the lowering (the
    epoch-order scratch recycling of `_pad_into`);
  * **recompilation guard**: the jaxpr hash is identical across
    (batch index, epoch, resume) variations — a changed hash means a
    value that should be a traced argument got captured as a constant
    (e.g. a weak-typed python scalar closed over instead of passed),
    which silently retraces per step and erases the pipeline overlap.

Everything here traces only (`jax.make_jaxpr` / `.lower()`): no kernel
is executed, so the audit runs in seconds on a CPU-only CI runner with
the Pallas paths in interpret mode.
"""
from __future__ import annotations

import hashlib
import re
from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

CALLBACK_MARKER = "callback"
F64 = np.dtype(np.float64)


def _is_f64(dtype) -> bool:
    try:
        return np.dtype(dtype) == F64
    except TypeError:       # extended dtypes (PRNG keys) are never f64
        return False


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------
def _subjaxprs(value) -> Iterable:
    """Jaxpr objects hiding inside an eqn param value (pjit bodies,
    custom_vjp branches, scan/while carries), detected by duck type so
    no internal jax.core classes are imported."""
    if hasattr(value, "eqns"):              # a Jaxpr
        yield value
    elif hasattr(value, "jaxpr"):           # a ClosedJaxpr
        yield value.jaxpr
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _subjaxprs(v)


def iter_eqns(jaxpr) -> Iterable:
    """Every eqn in `jaxpr` and, recursively, in nested sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


def _as_jaxpr(closed):
    return closed.jaxpr if hasattr(closed, "jaxpr") else closed


def primitive_counts(closed) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for eqn in iter_eqns(_as_jaxpr(closed)):
        name = eqn.primitive.name
        counts[name] = counts.get(name, 0) + 1
    return counts


def callback_eqns(closed) -> List[str]:
    return [e.primitive.name for e in iter_eqns(_as_jaxpr(closed))
            if CALLBACK_MARKER in e.primitive.name]


def f64_casts(closed) -> List[str]:
    """`convert_element_type` eqns whose target dtype is float64."""
    out = []
    for eqn in iter_eqns(_as_jaxpr(closed)):
        if eqn.primitive.name == "convert_element_type" and \
                _is_f64(eqn.params.get("new_dtype")):
            out.append(str(eqn))
    return out


def f64_avals(closed) -> List[str]:
    """Any eqn output with a float64 abstract value."""
    out = []
    for eqn in iter_eqns(_as_jaxpr(closed)):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and _is_f64(dtype):
                out.append(str(eqn))
    return out


def feature_gathers(closed, feat_dim: int) -> List[str]:
    """XLA `gather` eqns whose operand is a feature-shaped (rows, F)
    float matrix — the materialized fallback the fused kernels exist to
    avoid. 1-D int gathers (position-map lookups) and non-feature
    shapes are deliberately NOT flagged."""
    out = []
    for eqn in iter_eqns(_as_jaxpr(closed)):
        if eqn.primitive.name != "gather":
            continue
        aval = getattr(eqn.invars[0], "aval", None)
        shape = getattr(aval, "shape", ())
        dtype = getattr(aval, "dtype", None)
        if (dtype is not None and np.issubdtype(dtype, np.floating)
                and len(shape) == 2 and shape[1] == feat_dim):
            out.append(str(eqn))
    return out


_ADDR_RE = re.compile(r"0x[0-9a-f]+")


def jaxpr_hash(closed) -> str:
    """sha1 over the printed jaxpr — stable iff the trace is stable.
    Printed form includes shapes, dtypes, primitive params and constvar
    LITERALS, so a weak-typed scalar captured as a tracer-constant
    changes the hash while the same scalar passed as an argument does
    not. Memory addresses in function reprs (custom_jvp thunk params)
    are canonicalized out — they vary per process, not per trace."""
    text = _ADDR_RE.sub("0x0", str(closed))
    return hashlib.sha1(text.encode()).hexdigest()


def make_hash(fn: Callable, *args, **kwargs) -> str:
    return jaxpr_hash(jax.make_jaxpr(fn)(*args, **kwargs))


def _hygiene(closed, *, feat_dim: Optional[int] = None) -> dict:
    """The common per-artifact checks; `ok` is their conjunction."""
    cb, casts, avals = callback_eqns(closed), f64_casts(closed), \
        f64_avals(closed)
    rep = {"callbacks": len(cb), "f64_casts": len(casts),
           "f64_avals": len(avals)}
    if feat_dim is not None:
        fg = feature_gathers(closed, feat_dim)
        rep["feature_gathers"] = len(fg)
    rep["ok"] = all(v == 0 for k, v in rep.items() if k != "ok")
    return rep


# ---------------------------------------------------------------------------
# artifact audits
# ---------------------------------------------------------------------------
def audit_donation() -> dict:
    """`_pad_into` donates the previous epoch's order scratch; the
    lowering must carry the aliasing annotation (checked at the
    STABLEHLO level — works even on CPU where the runtime would ignore
    the donation and warn)."""
    from repro.pipeline.builder import _pad_into
    order = jnp.arange(96, dtype=jnp.int32)
    scratch = jnp.full((128,), -1, jnp.int32)
    text = _pad_into.lower(order, scratch).as_text()
    aliased = "tf.aliasing_output" in text
    return {"pad_into_aliased": aliased, "ok": aliased}


def audit_kernels(*, n_src: int = 64, n_dst: int = 16, r: int = 4,
                  feat_dim: int = 32, capacity: int = 8) -> dict:
    """gather_agg / gather_cached fwd+bwd on the declared Pallas path
    (interpret mode off-TPU): `pallas_call` present, no feature-shaped
    fallback gather, no callbacks, no f64."""
    from repro.kernels.gather_agg.ops import gather_agg
    from repro.kernels.gather_cached.ops import gather_cached

    x = jnp.ones((n_src, feat_dim), jnp.float32)
    idx = jnp.zeros((n_dst, r), jnp.int32)
    w = jnp.ones((n_dst, r), jnp.float32)

    def agg_fwd(x, idx, w):
        return gather_agg(x, idx, w, impl="pallas")

    def agg_loss(x, w):
        return gather_agg(x, idx, w, impl="pallas").sum()

    cache = jnp.ones((capacity, feat_dim), jnp.float32)
    pos = jnp.full((n_src,), -1, jnp.int32).at[:capacity].set(
        jnp.arange(capacity))
    ids = jnp.zeros((n_dst,), jnp.int32)

    def cached_fwd(cache, feats, pos, ids):
        return gather_cached(cache, feats, pos, ids, impl="pallas")

    def cached_loss(cache, feats):
        rows, _, _ = gather_cached(cache, feats, pos, ids, impl="pallas")
        return rows.sum()

    out = {}
    for name, closed in (
            ("gather_agg_fwd", jax.make_jaxpr(agg_fwd)(x, idx, w)),
            ("gather_agg_bwd", jax.make_jaxpr(
                jax.grad(agg_loss, argnums=(0, 1)))(x, w)),
            ("gather_cached_fwd", jax.make_jaxpr(cached_fwd)(
                cache, x, pos, ids)),
            ("gather_cached_bwd", jax.make_jaxpr(
                jax.grad(cached_loss, argnums=(0, 1)))(cache, x))):
        rep = _hygiene(closed, feat_dim=feat_dim)
        rep["pallas_calls"] = primitive_counts(closed).get("pallas_call", 0)
        rep["ok"] = rep["ok"] and rep["pallas_calls"] >= 1
        out[name] = rep
    out["ok"] = all(out[k]["ok"] for k in out if k != "ok")
    return out


def _policies() -> Dict[str, object]:
    from repro.batching.policy import make_policy
    return {name: make_policy(name)
            for name in ("rand", "norand", "comm_rand", "clustergcn",
                         "labor")}


def audit_device_order(graph, *, seed: int = 7) -> dict:
    """Per policy: the device epoch-order program is callback- and
    f64-free, and its jaxpr hash is identical across epochs AND across a
    fresh `OrderSpec` (resume): only the two uint32 epoch words may vary
    per epoch, and they ride in as arguments."""
    from repro.pipeline.device_order import (OrderSpec, device_epoch_order,
                                             epoch_words_for)
    out = {}
    for name, policy in _policies().items():
        spec = OrderSpec.for_policy(graph, policy)
        spec2 = OrderSpec.for_policy(graph, policy)     # resume: rebuilt

        hashes = [
            make_hash(lambda w: device_epoch_order(spec, w),
                      epoch_words_for(seed, 0)),
            make_hash(lambda w: device_epoch_order(spec, w),
                      epoch_words_for(seed, 1)),
            make_hash(lambda w: device_epoch_order(spec, w),
                      epoch_words_for(seed + 1, 0)),
            make_hash(lambda w: device_epoch_order(spec2, w),
                      epoch_words_for(seed, 0)),
        ]
        closed = jax.make_jaxpr(lambda w: device_epoch_order(spec, w))(
            epoch_words_for(seed, 0))
        rep = _hygiene(closed)
        rep["hash"] = hashes[0]
        rep["stable"] = len(set(hashes)) == 1
        rep["ok"] = rep["ok"] and rep["stable"]
        out[name] = rep
    out["ok"] = all(out[k]["ok"] for k in out if k != "ok")
    return out


def _trace_fused(builder, epoch: int, pos: int):
    """make_jaxpr over the fused build at cursor (epoch, pos), with
    everything per-batch — key, epoch, pos, the resident order, the
    shared sampler ctx — as traced ARGUMENTS, exactly as dispatched."""
    from repro.pipeline.builder import _fused_build
    b = builder
    order = b.epoch_roots(epoch)
    ctx = b.epoch_ranks(epoch)

    def traced(seed_key, e, p, order_pad, *maybe_ctx):
        shared = maybe_ctx[0] if maybe_ctx else None
        return _fused_build(seed_key, e, p, b.g, order_pad, b.labels,
                            shared, b.batch_size, b.fanouts, b.caps,
                            b.sampler)

    args = [b._seed_key, jnp.asarray(epoch, jnp.int32),
            jnp.asarray(pos, jnp.int32), order]
    if ctx is not None:
        args.append(ctx)
    return jax.make_jaxpr(traced)(*args)


def audit_fused_build(graph, *, batch_size: int = 128,
                      fanouts=(5, 5), caps=(512, 1024),
                      seed: int = 7) -> dict:
    """Per policy: the fused builder jaxpr is callback-/f64-free and its
    hash is invariant across (pos, epoch, fresh-builder resume) — the
    static args (B, fanouts, caps, sampler) are the ONLY trace keys, so
    every batch of every epoch reuses one compilation."""
    from repro.pipeline.builder import DeviceBatchBuilder
    out = {}
    for name, policy in _policies().items():
        b = DeviceBatchBuilder(graph, policy, batch_size, fanouts, caps,
                               seed=seed)
        b2 = DeviceBatchBuilder(graph, policy, batch_size, fanouts, caps,
                                seed=seed)              # resume: rebuilt
        closed = _trace_fused(b, 0, 0)
        hashes = [jaxpr_hash(closed),
                  jaxpr_hash(_trace_fused(b, 0, 1)),
                  jaxpr_hash(_trace_fused(b, 1, 0)),
                  jaxpr_hash(_trace_fused(b2, 0, 0))]
        rep = _hygiene(closed)
        rep["hash"] = hashes[0]
        rep["stable"] = len(set(hashes)) == 1
        rep["ok"] = rep["ok"] and rep["stable"]
        out[name] = rep
    out["ok"] = all(out[k]["ok"] for k in out if k != "ok")
    return out


def _make_trainer(graph, *, agg_impl: str = "auto", cache="dynamic:degree_hot",
                  seed: int = 3):
    from repro.batching.policy import make_policy
    from repro.configs.base import GNNConfig, TrainConfig
    from repro.train.gnn_loop import GNNTrainer
    cfg = GNNConfig("sage-audit", "sage", 2, 16, graph.feat_dim,
                    graph.num_classes, fanout=(5, 5), agg_impl=agg_impl)
    tcfg = TrainConfig(batch_size=128, max_epochs=1)
    return GNNTrainer(graph, cfg, tcfg, make_policy("comm_rand"),
                      caps=(512, 1024), eval_caps=(512, 1024), seed=seed,
                      cache=cache, pipeline="sync")


def _trace_train_step(tr, batch, *, poison: float = 1.0,
                      lr: float = 1e-3, key_seed: int = 0):
    return jax.make_jaxpr(tr.train_step)(
        tr.params, tr.opt_state, batch, tr.feats, tr.degrees, lr,
        jax.random.key(key_seed), tr.cache, poison, tr._skips)


def audit_train_step(graph) -> dict:
    """The guarded train step (dynamic cache attached, the richest
    path): no callbacks, no f64, and — the recompile guard — one jaxpr
    hash across poison on/off (the chaos scalar rides as a weak-typed
    ARGUMENT), lr changes, dropout keys, batch index and a fresh trainer
    (resume)."""
    from repro.pipeline.builder import DeviceBatchBuilder
    tr = _make_trainer(graph)
    b = DeviceBatchBuilder.from_stream(tr.stream)
    batch0, batch1 = b.build(0, 0), b.build(0, 1)

    closed = _trace_train_step(tr, batch0)
    hashes = [jaxpr_hash(closed),
              jaxpr_hash(_trace_train_step(tr, batch0,
                                           poison=float("nan"))),
              jaxpr_hash(_trace_train_step(tr, batch0, lr=3e-4,
                                           key_seed=5)),
              jaxpr_hash(_trace_train_step(tr, batch1))]
    tr2 = _make_trainer(graph)                          # resume: rebuilt
    b2 = DeviceBatchBuilder.from_stream(tr2.stream)
    hashes.append(jaxpr_hash(_trace_train_step(tr2, b2.build(0, 0))))

    rep = _hygiene(closed)
    rep["hash"] = hashes[0]
    rep["stable"] = len(set(hashes)) == 1
    rep["ok"] = rep["ok"] and rep["stable"]

    # the declared-Pallas config: kernels must show up as pallas_call
    tr_p = _make_trainer(graph, agg_impl="pallas")
    b_p = DeviceBatchBuilder.from_stream(tr_p.stream)
    closed_p = _trace_train_step(tr_p, b_p.build(0, 0))
    pallas = primitive_counts(closed_p).get("pallas_call", 0)
    rep["pallas"] = {
        "pallas_calls": pallas,
        **{k: v for k, v in _hygiene(closed_p).items() if k != "ok"}}
    rep["pallas"]["ok"] = pallas >= 1 and _hygiene(closed_p)["ok"]
    rep["ok"] = rep["ok"] and rep["pallas"]["ok"]

    # eval step rides along: same hygiene bar, no grad/guard machinery
    closed_e = jax.make_jaxpr(tr.eval_step)(
        tr.params, batch0, tr.feats, tr.degrees, tr.cache)
    rep["eval"] = _hygiene(closed_e)
    rep["ok"] = rep["ok"] and rep["eval"]["ok"]
    return rep


def _make_sharded_trainer(graph, mesh, *, seed: int = 3):
    from repro.batching.policy import make_policy
    from repro.configs.base import GNNConfig, TrainConfig
    from repro.train.gnn_loop import GNNTrainer
    cfg = GNNConfig("sage-audit", "sage", 2, 16, graph.feat_dim,
                    graph.num_classes, fanout=(5, 5))
    tcfg = TrainConfig(batch_size=128, max_epochs=1)
    # a STATIC cache plan rides along (the richest sharded path: cache
    # hits short-circuit the halo exchange inside the same jaxpr)
    return GNNTrainer(graph, cfg, tcfg, make_policy("comm_rand"),
                      caps=(512, 1024), eval_caps=(512, 1024), seed=seed,
                      cache="degree_hot", mesh=mesh)


def _trace_sharded_step(tr, epoch: int, pos: int, *, poison: float = 1.0,
                        lr: float = 1e-3, key_seed: int = 0):
    """Trace the shard_map-wrapped per-replica step exactly as the
    trainer dispatches it: batch from the sharded stream, the dropout
    key as raw key_data, poison/lr as weak-typed python scalars."""
    batch = tr.stream.build(tr.stream.root_batches(epoch)[pos], epoch, pos)
    step = tr._sharded_step_for(epoch)
    return jax.make_jaxpr(step.mapped)(
        tr.params, tr.opt_state, batch, tr._train_feats, tr.degrees, lr,
        jax.random.key_data(jax.random.key(key_seed)), tr.cache, poison,
        tr._skips)


def audit_sharded_step(graph, *, n_devices: int = 1) -> dict:
    """The `repro.dist.gnn` data-parallel step under the same contract
    as the single-device one: no callbacks, no f64, donation annotated,
    and ONE jaxpr hash across (poison, lr/key, batch index, fresh
    trainer = resume). Replica-index stability holds by construction —
    the step is a single SPMD program; `lax.axis_index` is a traced
    collective, so no per-replica trace exists to diverge — and the
    hash check on a fresh trainer pins that the HaloPlan (the only
    static input) replans identically.

    The sharded layer 0 consumes a halo-gathered (cap_L, F) table, so
    the single-device audit's no-feature-gather check does NOT apply
    here: table gathers from the (Ns, F) local shard are the exchange
    itself, not a kernel fallback."""
    from repro.dist import gnn as dist_gnn
    mesh = dist_gnn.make_gnn_mesh(n_devices)
    tr = _make_sharded_trainer(graph, mesh)
    closed = _trace_sharded_step(tr, 0, 0)
    hashes = [jaxpr_hash(closed),
              jaxpr_hash(_trace_sharded_step(tr, 0, 0,
                                             poison=float("nan"))),
              jaxpr_hash(_trace_sharded_step(tr, 0, 0, lr=3e-4,
                                             key_seed=5)),
              jaxpr_hash(_trace_sharded_step(tr, 0, 1))]
    tr2 = _make_sharded_trainer(graph, mesh)            # resume: rebuilt
    hashes.append(jaxpr_hash(_trace_sharded_step(tr2, 0, 0)))

    rep = _hygiene(closed)
    rep["n_devices"] = n_devices
    rep["hash"] = hashes[0]
    rep["stable"] = len(set(hashes)) == 1
    rep["spmd"] = True          # one program for every replica index
    counts = primitive_counts(closed)
    rep["psums"] = counts.get("psum", 0) + counts.get("psum2", 0)
    rep["halo_plan"] = {"mode": tr._hplan.mode, "halo": tr._hplan.halo,
                        "r_cap": tr._hplan.r_cap}

    # donation: the mesh-dispatch jit must carry the aliasing annotation
    # for params/opt (checked at the stablehlo level, as audit_donation)
    step = tr._sharded_step_for(0)
    batch = tr.stream.build(tr.stream.root_batches(0)[0], 0, 0)
    text = jax.jit(step.mapped, donate_argnums=(0, 1)).lower(
        tr.params, tr.opt_state, batch, tr._train_feats, tr.degrees,
        1e-3, jax.random.key_data(jax.random.key(0)), tr.cache, 1.0,
        tr._skips).as_text()
    rep["donation_aliased"] = "tf.aliasing_output" in text
    rep["ok"] = rep["ok"] and rep["stable"] and rep["donation_aliased"]
    return rep


def audit_all(graph=None) -> dict:
    """The full contract audit (the CLI's --jaxpr pass). `graph`
    defaults to the pinned `tiny` synthetic dataset — audits trace but
    never execute, so size only affects trace time."""
    if graph is None:
        from repro.core.reorder import prepare
        from repro.graphs.synthetic import load
        graph = prepare(load("tiny"), oracle=True)
    report = {
        "donation": audit_donation(),
        "kernels": audit_kernels(feat_dim=graph.feat_dim),
        "device_order": audit_device_order(graph),
        "fused_build": audit_fused_build(graph),
        "train_step": audit_train_step(graph),
        # 1-device mesh: the same SPMD program CI's forced-4-device dist
        # job audits, traceable on the default single-device runner
        "sharded_step": audit_sharded_step(graph),
    }
    report["ok"] = all(report[k]["ok"] for k in report if k != "ok")
    return report
