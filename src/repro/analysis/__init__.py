"""`repro.analysis`: determinism & jit-hygiene static analysis.

Two cooperating passes gate the training stack:

- **AST lint** (`lint.py` + `rules.py`): a pluggable rule registry over
  `ast` encoding the repo's written-but-unchecked invariants — no global
  numpy/stdlib randomness, no wall clock in deterministic modules, no
  host-sync idioms in hot-path functions, no f64 in device-facing code,
  structured `(seed, salt)` tuples for every `np.random.default_rng`,
  and no internal imports of deprecated shims. Legitimate uses carry a
  per-line `# analysis: allow[<rule>] -- justification` waiver.

- **Jaxpr contract auditor** (`jaxpr_audit.py`): traces the real jitted
  artifacts (guarded train step, `DeviceBatchBuilder._fused_build`,
  `device_epoch_order`, `gather_agg`/`gather_cached` fwd+bwd) and
  statically asserts: no callback primitives, no f64 casts, donation
  effective, Pallas paths actually contain `pallas_call` with no
  fallback feature gather, and the jaxpr hash is stable across
  (batch index, epoch, resume) variations — recompile drift is exactly
  the bug class that silently erases pipeline overlap wins.

Run locally with `python -m repro.analysis --strict` (or the
`repro-analysis` console script); CI runs both passes and uploads the
JSON report (`BENCH_analysis.json`-style: rule -> violations ->
waivers).
"""
from repro.analysis.config import AnalysisConfig
from repro.analysis.lint import LintReport, lint_paths, lint_source
from repro.analysis.rules import RULES, Violation

__all__ = [
    "AnalysisConfig",
    "LintReport",
    "lint_paths",
    "lint_source",
    "RULES",
    "Violation",
]
