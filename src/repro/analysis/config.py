"""Scoping configuration for the analysis pass.

The lint rules are scope-sensitive: wall-clock reads are fine in a
benchmark driver but not in the deterministic batch pipeline; `float()`
on an array is fine at an epoch boundary but not inside a function the
jitted step traces through. `AnalysisConfig` carries those scopes as
explicit module-prefix lists and a per-module hot-function map, so a
violation is always attributable to a named policy decision rather than
a heuristic.

Defaults here mirror `[tool.repro_analysis]` in `pyproject.toml`; the
CLI reads the pyproject block when present so CI and local runs agree.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

# Modules whose behaviour must be a pure function of (seed, cursor):
# wall-clock reads here are deterministic-contract violations unless
# explicitly waived (e.g. the prefetch watchdog's liveness heartbeats,
# which never influence delivered data).
DETERMINISTIC_PREFIXES: Tuple[str, ...] = (
    "repro/batching/",
    "repro/pipeline/",
    "repro/sampling/",
    "repro/featcache/",
    "repro/kernels/",
)

# module (path relative to src/) -> hot-path function names. Host-sync
# idioms inside these functions stall the dispatch queue or force a
# device round-trip per call. "*" marks every function in the module as
# hot (kernel bodies, model forward). Names cover methods too (bare
# method name, class-agnostic).
HOT_FUNCTIONS: Dict[str, Tuple[str, ...]] = {
    "repro/train/gnn_loop.py": ("train_step", "eval_step", "loss_fn",
                                "keep", "_train_one", "_guard_check",
                                "run_epoch", "train_steps", "evaluate",
                                "_evaluate"),
    "repro/pipeline/builder.py": ("_fused_build", "_pad_into",
                                  "_pad_fresh", "build", "_time_us"),
    "repro/pipeline/device_order.py": ("device_epoch_order",
                                       "_order_perm", "_order_comm_rand",
                                       "_order_clustergcn", "_hash_u32"),
    "repro/pipeline/prefetch.py": ("_produce",),
    # the tracer's hot-path entry points must themselves never sync:
    # tracing is sold as zero-device-impact, so the lint bans host-sync
    # idioms inside every function a traced step calls per span
    "repro/obs/trace.py": ("span", "instant", "note", "flush",
                           "_emit", "__enter__", "__exit__"),
    "repro/core/minibatch.py": ("_build_batch_impl", "_positions"),
    "repro/sampling/device.py": ("sample", "_sample_level", "_topk_mask",
                                 "_hash_rank01", "epoch_ctx"),
    "repro/featcache/dynamic.py": ("ref_updates", "with_refs",
                                   "_refill_jit", "_integrity_jit"),
    "repro/kernels/gather_agg/ops.py": ("*",),
    "repro/kernels/gather_agg/kernel.py": ("*",),
    "repro/kernels/gather_cached/ops.py": ("*",),
    "repro/kernels/gather_cached/kernel.py": ("*",),
    "repro/kernels/gather_mean/ops.py": ("*",),
    "repro/models/gnn/models.py": ("*",),
    "repro/models/gnn/fullgraph.py": ("*",),
}

# Modules that build device arrays: f64 literals/dtypes here leak into
# jaxprs (weak-type promotion or explicit casts) and double memory
# traffic on the feature path.
DEVICE_PREFIXES: Tuple[str, ...] = (
    "repro/kernels/",
    "repro/models/",
    "repro/sampling/device.py",
    "repro/pipeline/",
    "repro/featcache/dynamic.py",
    "repro/featcache/plan.py",
    "repro/train/gnn_loop.py",
)

# Host-side analytics that legitimately compute in f64 (modularity math,
# cache-simulator scores) and cast to f32 at the device boundary — the
# boundary casts are what `featcache/plan.py` tests pin.
F64_HOST_EXEMPT: Tuple[str, ...] = (
    "repro/core/community.py",
    "repro/featcache/sim.py",
    "repro/featcache/plan.py",
)

# Deprecated shims: importable for external callers during the
# deprecation window, but internal src/repro code must use the
# replacement module. The shim file itself is exempt (it re-exports).
DEPRECATED_MODULES: Dict[str, str] = {
    "repro.core.cachesim": "repro.featcache.sim",
    "repro.core.sampler": "repro.sampling",
}


@dataclass(frozen=True)
class AnalysisConfig:
    """Resolved scoping config consumed by `lint.py`."""
    deterministic_prefixes: Tuple[str, ...] = DETERMINISTIC_PREFIXES
    hot_functions: Dict[str, Tuple[str, ...]] = field(
        default_factory=lambda: dict(HOT_FUNCTIONS))
    device_prefixes: Tuple[str, ...] = DEVICE_PREFIXES
    f64_host_exempt: Tuple[str, ...] = F64_HOST_EXEMPT
    deprecated_modules: Dict[str, str] = field(
        default_factory=lambda: dict(DEPRECATED_MODULES))

    @classmethod
    def from_pyproject(cls, data: dict) -> "AnalysisConfig":
        """Build from a parsed `[tool.repro_analysis]` table; missing
        keys fall back to the module defaults above."""
        t = data.get("tool", {}).get("repro_analysis", {})
        kw = {}
        if "deterministic_prefixes" in t:
            kw["deterministic_prefixes"] = tuple(t["deterministic_prefixes"])
        if "device_prefixes" in t:
            kw["device_prefixes"] = tuple(t["device_prefixes"])
        if "f64_host_exempt" in t:
            kw["f64_host_exempt"] = tuple(t["f64_host_exempt"])
        if "hot_functions" in t:
            kw["hot_functions"] = {k: tuple(v)
                                   for k, v in t["hot_functions"].items()}
        if "deprecated_modules" in t:
            kw["deprecated_modules"] = dict(t["deprecated_modules"])
        return cls(**kw)

    # -- scope predicates (paths are relative to src/, posix separators)
    def in_deterministic(self, relpath: str) -> bool:
        return relpath.startswith(self.deterministic_prefixes)

    def in_device(self, relpath: str) -> bool:
        return (relpath.startswith(self.device_prefixes)
                and relpath not in self.f64_host_exempt)

    def hot_names(self, relpath: str) -> Tuple[str, ...]:
        return self.hot_functions.get(relpath, ())
