"""Lint rule registry: each rule encodes one written-but-unchecked
repo invariant as a pure function over a module's AST.

A rule is a callable `(tree, ctx) -> Iterable[Violation]` registered
under a stable kebab-case name (the name the waiver syntax and the JSON
report key on). Rules never read scope policy themselves — module
scoping (deterministic / device / hot-path) comes from `ctx.config`
(`AnalysisConfig`), so the same rule body runs everywhere and the
*policy* stays in one reviewable place.

Rule catalog:

  no-global-numpy-random   np.random.seed / module-level np.random.<fn>
  no-stdlib-random         any import of the stdlib `random` module
  no-wall-clock            time.time/monotonic/perf_counter, datetime.now
                           in deterministic modules
  no-host-sync-in-hot-path .item()/float()/int()/bool() on arrays,
                           np.asarray/np.array, jax.device_get,
                           block_until_ready inside hot-path functions
  no-f64-in-device-code    float64 dtypes/constants in device modules
  rng-structured-seed      np.random.default_rng must take a literal
                           (seed, salt, ...) tuple, never a bare int
  no-deprecated-import     internal imports of deprecation shims
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.analysis.config import AnalysisConfig


@dataclass
class Violation:
    rule: str
    path: str               # relative to src/
    line: int
    col: int
    message: str
    waived: bool = False
    justification: Optional[str] = None

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "waived": self.waived,
                "justification": self.justification}


@dataclass
class RuleContext:
    """Per-module state shared by every rule: resolved import aliases,
    a function-span index for hot-path scoping, and the scope config."""
    relpath: str
    config: AnalysisConfig
    aliases: Dict[str, str] = field(default_factory=dict)
    # (name, start_line, end_line) for every def, innermost-last
    func_spans: List[Tuple[str, int, int]] = field(default_factory=list)

    @classmethod
    def build(cls, relpath: str, tree: ast.AST,
              config: AnalysisConfig) -> "RuleContext":
        ctx = cls(relpath=relpath, config=config)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    ctx.aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    ctx.aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ctx.func_spans.append(
                    (node.name, node.lineno,
                     node.end_lineno or node.lineno))
        return ctx

    def resolves(self, name: str, module: str) -> bool:
        """Does local name `name` refer to `module` (via import alias)?"""
        return self.aliases.get(name) == module

    def enclosing_function(self, line: int) -> Optional[str]:
        """Name of the innermost def containing `line` (smallest span)."""
        best, best_size = None, None
        for name, lo, hi in self.func_spans:
            if lo <= line <= hi and (best_size is None
                                     or hi - lo < best_size):
                best, best_size = name, hi - lo
        return best

    def in_hot_function(self, line: int) -> bool:
        hot = self.config.hot_names(self.relpath)
        if not hot:
            return False
        if "*" in hot:
            return True
        fn = self.enclosing_function(line)
        return fn is not None and fn in hot


RuleFn = Callable[[ast.AST, RuleContext], Iterable[Violation]]
RULES: Dict[str, RuleFn] = {}


def rule(name: str) -> Callable[[RuleFn], RuleFn]:
    def deco(fn: RuleFn) -> RuleFn:
        RULES[name] = fn
        return fn
    return deco


def _v(name: str, ctx: RuleContext, node: ast.AST, msg: str) -> Violation:
    return Violation(rule=name, path=ctx.relpath, line=node.lineno,
                     col=node.col_offset, message=msg)


def _attr_chain(node: ast.AST) -> Optional[str]:
    """Flatten `a.b.c` to "a.b.c"; None for non-trivial bases."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _numpy_chain(chain: str, ctx: RuleContext) -> Optional[str]:
    """If `chain` starts at a numpy alias, return it rewritten with the
    canonical "numpy" root; else None."""
    root, _, rest = chain.partition(".")
    target = ctx.aliases.get(root, root)
    if target == "numpy":
        return f"numpy.{rest}" if rest else "numpy"
    if target.startswith("numpy."):
        return f"{target}.{rest}" if rest else target
    return None


# Constructors living under np.random that are deterministic-by-seed and
# therefore fine (everything else under np.random is the implicit global
# `RandomState`, which this repo bans).
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
                 "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937"}


@rule("no-global-numpy-random")
def no_global_numpy_random(tree: ast.AST,
                           ctx: RuleContext) -> Iterable[Violation]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Attribute):
            continue
        chain = _attr_chain(node)
        if chain is None:
            continue
        np_chain = _numpy_chain(chain, ctx)
        if np_chain is None or not np_chain.startswith("numpy.random."):
            continue
        leaf = np_chain.split(".")[2]
        if leaf not in _NP_RANDOM_OK:
            yield _v("no-global-numpy-random", ctx, node,
                     f"module-level numpy randomness `{chain}` — use a "
                     f"seeded Generator or the counter-based hash path "
                     f"in batching/order.py")


@rule("no-stdlib-random")
def no_stdlib_random(tree: ast.AST, ctx: RuleContext) -> Iterable[Violation]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "random" or a.name.startswith("random."):
                    yield _v("no-stdlib-random", ctx, node,
                             "stdlib `random` is process-global state — "
                             "use np.random.default_rng((seed, salt))")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "random":
                yield _v("no-stdlib-random", ctx, node,
                         "stdlib `random` is process-global state — "
                         "use np.random.default_rng((seed, salt))")


_WALL_CLOCK = {
    "time": {"time", "monotonic", "perf_counter", "process_time",
             "thread_time", "monotonic_ns", "perf_counter_ns", "time_ns"},
    "datetime": {"now", "utcnow", "today"},
}


@rule("no-wall-clock")
def no_wall_clock(tree: ast.AST, ctx: RuleContext) -> Iterable[Violation]:
    if not ctx.config.in_deterministic(ctx.relpath):
        return
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        chain = _attr_chain(node.func)
        if chain is None:
            continue
        root, *rest = chain.split(".")
        target = ctx.aliases.get(root, root)
        if target == "time" and rest and rest[-1] in _WALL_CLOCK["time"]:
            yield _v("no-wall-clock", ctx, node,
                     f"wall-clock read `{chain}()` in a deterministic "
                     f"module — output must be a pure function of "
                     f"(seed, cursor)")
        elif (target in ("datetime", "datetime.datetime")
              and rest and rest[-1] in _WALL_CLOCK["datetime"]):
            yield _v("no-wall-clock", ctx, node,
                     f"wall-clock read `{chain}()` in a deterministic "
                     f"module")


_SYNC_ATTRS = {"item", "block_until_ready"}
_SYNC_NP = {"asarray", "array", "ascontiguousarray"}
_SYNC_JAX = {"device_get", "block_until_ready"}


@rule("no-host-sync-in-hot-path")
def no_host_sync(tree: ast.AST, ctx: RuleContext) -> Iterable[Violation]:
    if not ctx.config.hot_names(ctx.relpath):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if not ctx.in_hot_function(node.lineno):
            continue
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("float", "int", "bool"):
            if node.args and not isinstance(node.args[0], ast.Constant):
                yield _v("no-host-sync-in-hot-path", ctx, node,
                         f"`{f.id}()` on a (possibly device) value in "
                         f"hot-path function "
                         f"`{ctx.enclosing_function(node.lineno)}` — "
                         f"forces a blocking device->host transfer")
            continue
        if not isinstance(f, ast.Attribute):
            continue
        chain = _attr_chain(f)
        if f.attr in _SYNC_ATTRS and (chain is None
                                      or "." in (chain or "")):
            yield _v("no-host-sync-in-hot-path", ctx, node,
                     f"`.{f.attr}()` in hot-path function "
                     f"`{ctx.enclosing_function(node.lineno)}` — "
                     f"synchronizes with the device")
            continue
        if chain is None:
            continue
        root, *rest = chain.split(".")
        target = ctx.aliases.get(root, root)
        if target == "numpy" and rest and rest[-1] in _SYNC_NP:
            yield _v("no-host-sync-in-hot-path", ctx, node,
                     f"`{chain}()` in hot-path function "
                     f"`{ctx.enclosing_function(node.lineno)}` — pulls "
                     f"the operand to host memory")
        elif target == "jax" and rest and rest[-1] in _SYNC_JAX:
            yield _v("no-host-sync-in-hot-path", ctx, node,
                     f"`{chain}()` in hot-path function "
                     f"`{ctx.enclosing_function(node.lineno)}`")


@rule("no-f64-in-device-code")
def no_f64_device(tree: ast.AST, ctx: RuleContext) -> Iterable[Violation]:
    if not ctx.config.in_device(ctx.relpath):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr in ("float64",
                                                             "double"):
            chain = _attr_chain(node)
            if chain is None:
                continue
            root = chain.split(".")[0]
            target = ctx.aliases.get(root, root)
            if target in ("numpy", "jax.numpy", "jax"):
                yield _v("no-f64-in-device-code", ctx, node,
                         f"`{chain}` in device-facing code — the stack "
                         f"is f32/int32; f64 doubles feature-path "
                         f"memory traffic")
        elif (isinstance(node, ast.Constant)
              and node.value in ("float64", "f8", ">f8", "<f8")):
            yield _v("no-f64-in-device-code", ctx, node,
                     f"dtype string {node.value!r} in device-facing code")


@rule("rng-structured-seed")
def rng_structured_seed(tree: ast.AST,
                        ctx: RuleContext) -> Iterable[Violation]:
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "default_rng"):
            continue
        chain = _attr_chain(node.func)
        if chain is None or _numpy_chain(chain, ctx) is None:
            continue
        if not node.args and not node.keywords:
            yield _v("rng-structured-seed", ctx, node,
                     "`default_rng()` with no seed draws OS entropy — "
                     "nondeterministic")
        elif node.args and not isinstance(node.args[0], ast.Tuple):
            yield _v("rng-structured-seed", ctx, node,
                     "`default_rng` seed must be a literal structured "
                     "tuple `(seed, salt, ...)` so independent streams "
                     "can never collide on a shared bare int")


@rule("no-deprecated-import")
def no_deprecated_import(tree: ast.AST,
                         ctx: RuleContext) -> Iterable[Violation]:
    deprecated = ctx.config.deprecated_modules
    shim_paths = {m.replace(".", "/") + ".py" for m in deprecated}
    if ctx.relpath in shim_paths:
        return                  # the shim itself re-exports; that's fine
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name in deprecated:
                    yield _v("no-deprecated-import", ctx, node,
                             f"`{a.name}` is a deprecation shim — "
                             f"import `{deprecated[a.name]}` instead")
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module in deprecated:
                yield _v("no-deprecated-import", ctx, node,
                         f"`{node.module}` is a deprecation shim — "
                         f"import `{deprecated[node.module]}` instead")
            else:
                for a in node.names:
                    full = f"{node.module}.{a.name}"
                    if full in deprecated:
                        yield _v("no-deprecated-import", ctx, node,
                                 f"`{full}` is a deprecation shim — "
                                 f"import `{deprecated[full]}` instead")
