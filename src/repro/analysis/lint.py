"""Lint engine: run every registered rule over a file set, apply
per-line waivers, and produce a `LintReport`.

Waiver syntax (audited, not silencing): a violation is *waived* — kept
in the report, excluded from the strict gate — when the offending line,
or the line directly above it, carries

    # analysis: allow[<rule-name>] -- justification

The justification is mandatory under `--strict`: a waiver that names a
rule but gives no reason still fails the gate, so every exemption in
the tree documents *why* the invariant does not apply (e.g. the
prefetch watchdog's heartbeat reads wall clock for liveness only and
never influences delivered data).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.config import AnalysisConfig
from repro.analysis.rules import RULES, RuleContext, Violation

_WAIVER_RE = re.compile(
    r"#\s*analysis:\s*allow\[([a-z0-9-]+)\]\s*(?:--\s*)?(.*?)\s*$")


def parse_waivers(source: str) -> Dict[Tuple[int, str], str]:
    """Map (covered_line, rule) -> justification. A waiver comment
    covers its own line; a comment-only line also covers the next."""
    waivers: Dict[Tuple[int, str], str] = {}
    lines = source.splitlines()
    for i, line in enumerate(lines, start=1):
        m = _WAIVER_RE.search(line)
        if not m:
            continue
        rule_name, why = m.group(1), m.group(2).strip()
        waivers[(i, rule_name)] = why
        if line.lstrip().startswith("#"):       # standalone comment line
            waivers[(i + 1, rule_name)] = why
    return waivers


@dataclass
class LintReport:
    violations: List[Violation] = field(default_factory=list)
    files_checked: int = 0
    unknown_waivers: List[dict] = field(default_factory=list)

    @property
    def unwaived(self) -> List[Violation]:
        return [v for v in self.violations if not v.waived]

    @property
    def waived(self) -> List[Violation]:
        return [v for v in self.violations if v.waived]

    def unjustified(self) -> List[Violation]:
        return [v for v in self.waived if not (v.justification or "").strip()]

    def strict_ok(self) -> bool:
        """The CI gate: zero unwaived violations AND every waiver
        carries a non-empty justification."""
        return not self.unwaived and not self.unjustified()

    def to_json(self) -> dict:
        by_rule: Dict[str, dict] = {
            name: {"violations": [], "waivers": []} for name in RULES}
        for v in self.violations:
            key = "waivers" if v.waived else "violations"
            by_rule.setdefault(
                v.rule, {"violations": [], "waivers": []})[key].append(
                v.to_json())
        return {
            "files_checked": self.files_checked,
            "strict_ok": self.strict_ok(),
            "n_violations": len(self.unwaived),
            "n_waived": len(self.waived),
            "rules": by_rule,
            "unknown_waivers": self.unknown_waivers,
        }


def lint_source(source: str, relpath: str,
                config: Optional[AnalysisConfig] = None,
                rules: Optional[Sequence[str]] = None) -> List[Violation]:
    """Lint one module given as text (the unit tests' entry point).
    `relpath` is the path relative to src/ (posix separators) and is
    what scoping predicates key on."""
    config = config or AnalysisConfig()
    tree = ast.parse(source, filename=relpath)
    ctx = RuleContext.build(relpath, tree, config)
    waivers = parse_waivers(source)
    out: List[Violation] = []
    for name, fn in RULES.items():
        if rules is not None and name not in rules:
            continue
        for v in fn(tree, ctx) or ():
            why = waivers.get((v.line, v.rule))
            if why is not None:
                v.waived = True
                v.justification = why
            out.append(v)
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def iter_py_files(root: Path) -> Iterable[Path]:
    yield from sorted(root.rglob("*.py"))


def lint_paths(src_root: Path,
               config: Optional[AnalysisConfig] = None) -> LintReport:
    """Lint every .py under `src_root` (the src/ directory)."""
    config = config or AnalysisConfig()
    report = LintReport()
    known = set(RULES)
    for path in iter_py_files(src_root):
        relpath = path.relative_to(src_root).as_posix()
        source = path.read_text()
        report.files_checked += 1
        report.violations.extend(lint_source(source, relpath, config))
        for (line, rule_name), _ in parse_waivers(source).items():
            if rule_name not in known:
                report.unknown_waivers.append(
                    {"path": relpath, "line": line, "rule": rule_name})
    report.violations.sort(key=lambda v: (v.path, v.line, v.rule))
    return report
