"""Synthetic community-structured graphs (offline stand-ins for
reddit / ogbn-products / igb — see DESIGN.md §7).

Generator: degree-corrected stochastic block model with power-law-ish
community sizes, label-correlated features, and the paper's train/val/test
split ratios. Nodes are emitted in RANDOM order (like the raw datasets);
community-based reordering is an explicit preprocessing step, as in the
paper.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graphs.csr import Graph, symmetrize


@dataclass(frozen=True)
class SBMSpec:
    name: str
    num_nodes: int = 20_000
    num_communities: int = 40
    avg_degree: float = 20.0
    p_intra: float = 0.9          # fraction of edge endpoints intra-community
    feat_dim: int = 64
    num_classes: int = 16
    label_noise: float = 0.1
    feat_noise: float = 1.0
    train_frac: float = 0.66      # reddit-like by default
    val_frac: float = 0.10
    community_size_skew: float = 1.3   # >1: power-lawish sizes
    seed: int = 0


# dataset registry: scaled-down mirrors of the paper's four graphs
REDDIT_LIKE = SBMSpec("reddit-like", 20_000, 40, 40.0, 0.9, 64, 16,
                      train_frac=0.66, val_frac=0.10, seed=1)
PRODUCTS_LIKE = SBMSpec("products-like", 50_000, 120, 25.0, 0.92, 50, 32,
                        train_frac=0.08, val_frac=0.02, seed=2)
IGB_LIKE = SBMSpec("igb-like", 30_000, 64, 13.0, 0.88, 96, 19,
                   train_frac=0.60, val_frac=0.20, seed=3)
PAPERS_LIKE = SBMSpec("papers-like", 80_000, 200, 18.0, 0.94, 32, 24,
                      train_frac=0.011, val_frac=0.001, seed=4)
TINY = SBMSpec("tiny", 2_000, 8, 12.0, 0.9, 16, 4, seed=5)

DATASETS = {s.name: s for s in
            (REDDIT_LIKE, PRODUCTS_LIKE, IGB_LIKE, PAPERS_LIKE, TINY)}


def _community_sizes(rng, spec) -> np.ndarray:
    w = rng.pareto(spec.community_size_skew, spec.num_communities) + 1.0
    sizes = np.maximum((w / w.sum() * spec.num_nodes).astype(np.int64), 8)
    # fix rounding so sizes sum to N
    diff = spec.num_nodes - sizes.sum()
    sizes[np.argmax(sizes)] += diff
    return sizes


def generate(spec: SBMSpec) -> Graph:
    # salt 0 = legacy stream slot: trailing-zero SeedSequence tuples
    # spawn the SAME stream as the bare int, so every pinned DATASETS
    # graph is bit-identical to pre-conversion builds
    rng = np.random.default_rng((spec.seed, 0))
    N, C = spec.num_nodes, spec.num_communities
    sizes = _community_sizes(rng, spec)
    comm_of = np.repeat(np.arange(C, dtype=np.int32), sizes)
    # emit nodes in random order (raw datasets are not community-sorted)
    shuffle = rng.permutation(N)
    comm_of = comm_of[shuffle]

    # --- edges: degree-corrected SBM ---
    E_target = int(N * spec.avg_degree / 2)
    # node propensity (power-law degrees)
    theta = rng.pareto(2.0, N) + 1.0
    members = [np.where(comm_of == c)[0] for c in range(C)]
    mem_theta = [theta[m] / theta[m].sum() for m in members]

    n_intra_e = int(E_target * spec.p_intra)
    n_inter_e = E_target - n_intra_e
    # intra edges: pick community ~ size, endpoints ~ theta within it
    comm_w = np.array([t.sum() for t in
                       (theta[m] for m in members)])
    comm_w = comm_w / comm_w.sum()
    cs = rng.choice(C, n_intra_e, p=comm_w)
    src = np.empty(E_target, np.int64)
    dst = np.empty(E_target, np.int64)
    counts = np.bincount(cs, minlength=C)
    o = 0
    for c in range(C):
        k = counts[c]
        if k == 0:
            continue
        m, w = members[c], mem_theta[c]
        src[o:o + k] = rng.choice(m, k, p=w)
        dst[o:o + k] = rng.choice(m, k, p=w)
        o += k
    # inter edges: uniform-ish theta-weighted across graph
    pw = theta / theta.sum()
    src[o:] = rng.choice(N, n_inter_e, p=pw)
    dst[o:] = rng.choice(N, n_inter_e, p=pw)

    keep = src != dst
    src, dst = src[keep], dst[keep]
    indptr = np.zeros(N + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    order = np.argsort(src, kind="stable")
    indices = dst[order].astype(np.int32)
    indptr, indices = symmetrize(indptr, indices)

    # --- labels: communities map to classes (several communities share a
    # class), plus noise so the task is non-trivial ---
    class_of_comm = rng.integers(0, spec.num_classes, C)
    labels = class_of_comm[comm_of].astype(np.int32)
    flip = rng.random(N) < spec.label_noise
    labels[flip] = rng.integers(0, spec.num_classes, flip.sum())

    # --- features: class centroid + community offset + noise ---
    class_mu = rng.normal(0, 1, (spec.num_classes, spec.feat_dim))
    comm_mu = rng.normal(0, 0.5, (C, spec.feat_dim))
    feats = (class_mu[labels] + comm_mu[comm_of]
             + rng.normal(0, spec.feat_noise, (N, spec.feat_dim)))
    feats = feats.astype(np.float32)

    # --- splits ---
    perm = rng.permutation(N)
    n_tr = int(N * spec.train_frac)
    n_va = int(N * spec.val_frac)
    g = Graph(
        indptr=indptr, indices=indices, features=feats, labels=labels,
        train_ids=np.sort(perm[:n_tr]),
        val_ids=np.sort(perm[n_tr:n_tr + n_va]),
        test_ids=np.sort(perm[n_tr + n_va:]),
        communities=comm_of,       # ground-truth ("oracle") communities
        name=spec.name,
    )
    return g


def load(name: str) -> Graph:
    return generate(DATASETS[name])
