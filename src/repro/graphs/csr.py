"""CSR graph substrate (host-side numpy + device-side jnp mirrors).

The device mirror stores the *intra-first* row layout: each adjacency row is
re-ordered so intra-community edges come first and `n_intra[u]` records the
split point — this turns the paper's biased neighbor sampling (probability p
for intra-community edges) into a two-phase draw with O(1) per-sample work
and no per-edge weight array.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Graph:
    indptr: np.ndarray           # (N+1,) int64
    indices: np.ndarray          # (E,) int32
    features: np.ndarray         # (N, F) float32
    labels: np.ndarray           # (N,) int32
    train_ids: np.ndarray
    val_ids: np.ndarray
    test_ids: np.ndarray
    communities: Optional[np.ndarray] = None   # (N,) int32
    n_intra: Optional[np.ndarray] = None       # (N,) int32 (intra-first rows)
    name: str = "graph"

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)


def symmetrize(indptr, indices):
    """Make the graph undirected (union with reverse edges), dedup."""
    N = len(indptr) - 1
    src = np.repeat(np.arange(N, dtype=np.int64), np.diff(indptr))
    dst = indices.astype(np.int64)
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    key = u * N + v
    key = np.unique(key)
    u, v = key // N, (key % N).astype(np.int32)
    new_indptr = np.zeros(N + 1, np.int64)
    np.add.at(new_indptr, u + 1, 1)
    np.cumsum(new_indptr, out=new_indptr)
    return new_indptr, v


def reorder(graph: Graph, perm: np.ndarray) -> Graph:
    """Relabel nodes: new_id = perm_inv[old_id]; node `perm[i]` becomes `i`."""
    N = graph.num_nodes
    perm_inv = np.empty(N, np.int64)
    perm_inv[perm] = np.arange(N)
    old_deg = graph.degrees()
    new_indptr = np.zeros(N + 1, np.int64)
    np.cumsum(old_deg[perm], out=new_indptr[1:])
    # vectorized row move: edge e of old node u keeps its within-row offset
    # and lands at new row perm_inv[u] — one gather/scatter over the edge
    # array instead of a per-node Python loop
    src = np.repeat(np.arange(N, dtype=np.int64), old_deg)
    offs = np.arange(graph.num_edges, dtype=np.int64) - graph.indptr[src]
    new_indices = np.empty_like(graph.indices)
    new_indices[new_indptr[perm_inv[src]] + offs] = perm_inv[graph.indices]
    out = replace(
        graph,
        indptr=new_indptr,
        indices=new_indices.astype(np.int32),
        features=graph.features[perm],
        labels=graph.labels[perm],
        train_ids=np.sort(perm_inv[graph.train_ids]).astype(np.int64),
        val_ids=np.sort(perm_inv[graph.val_ids]).astype(np.int64),
        test_ids=np.sort(perm_inv[graph.test_ids]).astype(np.int64),
        communities=graph.communities[perm]
        if graph.communities is not None else None,
        n_intra=None,       # row layout must be rebuilt after relabeling
    )
    return out


def intra_first_layout(graph: Graph) -> Graph:
    """Reorder each adjacency row: intra-community neighbors first."""
    assert graph.communities is not None
    comm = graph.communities
    src = np.repeat(np.arange(graph.num_nodes), graph.degrees())
    intra = comm[src] == comm[graph.indices]
    # stable sort within rows: key = (row, ~intra)
    order = np.lexsort((~intra, src))
    new_indices = graph.indices[order]
    n_intra = np.zeros(graph.num_nodes, np.int32)
    np.add.at(n_intra, src[intra], 1)
    return replace(graph, indices=new_indices, n_intra=n_intra)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["indptr", "indices", "n_intra", "communities", "degrees"],
    meta_fields=["num_nodes", "max_degree"])
@dataclass
class DeviceGraph:
    """jnp mirrors used by the jit-compiled samplers/batch builder.

    `max_degree` is static metadata: the LABOR sampler's shared-rank
    top-k gathers an (M, max_degree) candidate tile, so the bound must be
    known at trace time."""
    indptr: jnp.ndarray
    indices: jnp.ndarray
    n_intra: jnp.ndarray
    communities: jnp.ndarray
    degrees: jnp.ndarray
    num_nodes: int
    max_degree: int = 0

    @staticmethod
    def from_graph(g: Graph) -> "DeviceGraph":
        assert g.n_intra is not None, "run intra_first_layout first"
        deg = g.degrees()
        # int32 offsets: fine below ~2^31 edges; the pod-scale pipeline keeps
        # topology on hosts (DESIGN.md §4) so this bound is per-host.
        return DeviceGraph(
            indptr=jnp.asarray(g.indptr, jnp.int32),
            indices=jnp.asarray(g.indices, jnp.int32),
            n_intra=jnp.asarray(g.n_intra, jnp.int32),
            communities=jnp.asarray(g.communities, jnp.int32),
            degrees=jnp.asarray(deg, jnp.int32),
            num_nodes=g.num_nodes,
            max_degree=int(deg.max()) if len(deg) else 0,
        )
