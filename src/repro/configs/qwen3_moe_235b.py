"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA kv=4, qk-norm.
[hf:Qwen/Qwen3-235B-A22B family]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    attention="full",
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=True,
    num_experts=128,
    top_k=8,
    moe_d_ff=1536,
    act="silu",
)
