"""GraphSAGE (the paper's primary model) — 3 layers, hidden 256, fanout 10.

Paper §5: DGL reference defaults (batch=1024, fanout=10, lr=1e-3,
weight_decay=5e-4, hidden=256).
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="graphsage",
    model="sage",
    num_layers=3,
    hidden_dim=256,
    in_dim=602,                   # reddit-like
    num_classes=41,
    fanout=(10, 10, 10),
)
