"""qwen1.5-32b [dense] — MHA (kv=40), QKV bias. [hf:Qwen/Qwen1.5-32B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    attention="full",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="silu",
)
