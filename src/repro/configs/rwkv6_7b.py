"""rwkv6-7b "Finch" [ssm] — attention-free, data-dependent decay.
[arXiv:2404.05892]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,                 # wkv heads, head_dim 64
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    attention="none",
    rwkv=True,
    act="relu2",                  # rwkv channel-mix uses relu^2
)
