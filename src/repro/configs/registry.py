"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

import importlib
from typing import Dict, Union

from repro.configs.base import GNNConfig, ModelConfig

# arch id (as assigned) -> module name
_LM_MODULES = {
    "gemma3-27b": "gemma3_27b",
    "gemma3-1b": "gemma3_1b",
    "qwen2-72b": "qwen2_72b",
    "qwen1.5-32b": "qwen15_32b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "rwkv6-7b": "rwkv6_7b",
    "hymba-1.5b": "hymba_1_5b",
}
_GNN_MODULES = {
    "graphsage": "graphsage",
    "gcn": "gcn",
    "gat": "gat",
}

LM_ARCHS = tuple(_LM_MODULES)
GNN_ARCHS = tuple(_GNN_MODULES)
ALL_ARCHS = LM_ARCHS + GNN_ARCHS


def get_config(arch: str) -> Union[ModelConfig, GNNConfig]:
    mods = dict(_LM_MODULES)
    mods.update(_GNN_MODULES)
    if arch not in mods:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(mods)}")
    mod = importlib.import_module(f"repro.configs.{mods[arch]}")
    return mod.CONFIG


def lm_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in LM_ARCHS}


def gnn_configs() -> Dict[str, GNNConfig]:
    return {a: get_config(a) for a in GNN_ARCHS}
