"""whisper-large-v3 [audio] — encoder-decoder; conv frontend is a stub
(`input_specs` supplies precomputed (B, 1500, d_model) frame embeddings).
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,                # decoder layers
    num_encoder_layers=32,
    encoder_decoder=True,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,             # padded to 51968 for TP
    attention="full",
    act="gelu",
    norm="layernorm",
    mlp_bias=True,
    learned_pos=True,
)
