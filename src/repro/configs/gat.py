"""GAT (paper §6.4 generalization study)."""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gat",
    model="gat",
    num_layers=3,
    hidden_dim=256,
    in_dim=602,
    num_classes=41,
    fanout=(10, 10, 10),
    gat_heads=4,
)
