"""Config dataclasses shared by the whole framework.

Everything is a frozen dataclass so configs are hashable and usable as jit
static arguments. `ModelConfig` covers all 10 assigned LM-family archs via
feature flags; `GNNConfig` covers the paper's own models.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# LM-family model config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention flavor -------------------------------------------------
    attention: str = "full"          # full | sliding | mixed | none
    window: int = 1024               # sliding-window size (mixed/sliding)
    global_every: int = 6            # in "mixed": every Nth layer is global
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False              # 3-axis multimodal RoPE (qwen2-vl)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # t/h/w split of head_dim/2

    # --- MoE ---------------------------------------------------------------
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0             # qwen2-moe shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- SSM / RWKV ---------------------------------------------------------
    ssm_state: int = 0               # mamba-style state size (hymba)
    rwkv: bool = False               # attention-free RWKV6 token mixing
    hybrid: bool = False             # parallel attn + SSM heads (hymba)

    # --- encoder-decoder (whisper) -----------------------------------------
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30s @ 50Hz post-conv frames

    # --- VLM stub ------------------------------------------------------------
    vision_tokens: int = 0           # leading positions carrying patch embeds

    # --- misc ----------------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"                # silu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm (whisper)
    mlp_bias: bool = False           # whisper uses biased linears
    learned_pos: bool = False        # whisper decoder positions
    logit_softcap: float = 0.0       # gemma-style tanh soft-capping (unused=0)
    dtype: str = "bfloat16"          # compute dtype

    # -----------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP-16/32 sharding divides."""
        return _round_up(self.vocab_size, 256)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def is_global_layer(self, i: int) -> bool:
        if self.attention == "full":
            return True
        if self.attention == "sliding":
            return False
        # "mixed": gemma3 pattern — every `global_every`-th layer is global
        return (i % self.global_every) == (self.global_every - 1)

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        # keep GQA ratio flavor: if original had kv < heads, keep kv < heads
        if self.num_kv_heads < self.num_heads:
            kv = max(1, heads // 2)
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            window=16,
            global_every=2,
            encoder_seq=24,
        )
        if self.moe:
            kw.update(num_experts=min(self.num_experts, 8),
                      top_k=min(self.top_k, 2), moe_d_ff=32,
                      shared_d_ff=64 if self.shared_d_ff else 0)
        if self.num_encoder_layers:
            kw.update(num_encoder_layers=2)
        if self.ssm_state:
            kw.update(ssm_state=4)
        if self.vision_tokens:
            kw.update(vision_tokens=8)
        if self.mrope:
            kw.update(mrope_sections=(2, 3, 3))   # half of head_dim 16
        return self.scaled(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def long_context_ok(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (see DESIGN.md §5)."""
    return cfg.rwkv or cfg.hybrid or cfg.attention in ("sliding", "mixed")


# ---------------------------------------------------------------------------
# GNN config (the paper's own models)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class GNNConfig:
    name: str
    model: str = "sage"              # sage | gcn | gat
    num_layers: int = 3
    hidden_dim: int = 256
    in_dim: int = 602
    num_classes: int = 41
    fanout: Tuple[int, ...] = (10, 10, 10)
    gat_heads: int = 4
    dropout: float = 0.5
    dtype: str = "float32"
    # aggregation backend: "auto" picks the fused repro.kernels.gather_agg
    # Pallas kernel on TPU and the jnp reference elsewhere; "pallas" forces
    # the kernel (interpret-mode simulator off-TPU — validation only)
    agg_impl: str = "auto"           # auto | jnp | pallas


# ---------------------------------------------------------------------------
# COMM-RAND policy knobs (the paper's contribution, §4)
#
# DEPRECATED import location: `CommRandPolicy` lives in
# `repro.batching.policy` now, registered alongside the other batch
# policies ("rand" / "norand" / "comm_rand" / "clustergcn" / "labor").
# This re-export is a shim for existing callers.
# ---------------------------------------------------------------------------
from repro.batching.policy import CommRandPolicy  # noqa: E402,F401

BASELINE_POLICY = CommRandPolicy("rand", 0.0, 0.5)
NORAND_POLICY = CommRandPolicy("norand", 0.0, 1.0)
BEST_POLICY = CommRandPolicy("comm_rand", 0.125, 1.0)   # paper §6.1.3


# ---------------------------------------------------------------------------
# Training hyper-params (paper §5 defaults)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 1024
    learning_rate: float = 1e-3
    weight_decay: float = 5e-4
    max_epochs: int = 100
    early_stop_patience: int = 6
    plateau_patience: int = 3
    plateau_factor: float = 0.1
    seed: int = 0
    # LM trainer extras
    grad_clip: float = 1.0
    microbatches: int = 1
    remat: bool = True
    grad_compression: bool = False


# ---------------------------------------------------------------------------
# Mesh config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))
