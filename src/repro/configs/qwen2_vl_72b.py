"""qwen2-vl-72b [vlm] — qwen2-72b backbone + M-RoPE; vision frontend is a
stub (`input_specs` supplies precomputed patch embeddings merged into the
leading `vision_tokens` positions). [arXiv:2409.12191]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    attention="full",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope=True,
    mrope_sections=(16, 24, 24),   # t/h/w split of head_dim/2 = 64
    vision_tokens=1024,
    act="silu",
)
