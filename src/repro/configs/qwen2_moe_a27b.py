"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + shared expert.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                    # = moe expert ff (per assignment)
    vocab_size=151936,
    attention="full",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe=True,
    num_experts=60,
    top_k=4,
    moe_d_ff=1408,
    shared_d_ff=5632,             # "4 shared" = one shared expert of 4x width
    act="silu",
)
