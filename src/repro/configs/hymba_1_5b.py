"""hymba-1.5b [hybrid] — parallel attention + mamba heads, ssm_state=16.
[arXiv:2411.13676]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,             # padded to 32256 for TP
    attention="mixed",            # SWA with periodic global layers
    window=1024,
    global_every=16,
    ssm_state=16,
    hybrid=True,
    act="silu",
)
