"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

Source: hf:google/gemma-3-27b-it (family config style per gemma-3-1b-pt card).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    attention="mixed",        # 5 local : 1 global
    window=1024,
    global_every=6,
    qk_norm=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    act="gelu",
)
