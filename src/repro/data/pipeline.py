"""Host-side data pipeline for the LM trainer.

`BlockShuffler` is the generic form of the paper's biased root partitioning
(DESIGN.md §5): the corpus is treated as blocks (shards / domains /
communities); blocks are shuffled as wholes, groups of `mix` blocks merge
into super-blocks whose contents are shuffled — giving shard-local read
locality with controlled randomness. The operator itself lives in
`repro.batching.order.block_shuffle`; `core.partition.epoch_order` applies
the same operator to graph communities.

The stream carries an explicit cursor (epoch, position) — the shared
`repro.batching.Cursor` — that is part of every checkpoint; resume is
bit-exact.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np

from repro.batching.order import block_shuffle
from repro.batching.stream import Cursor  # noqa: F401 — shared re-export


@dataclass
class BlockShuffler:
    num_items: int
    block_size: int
    mix: float = 0.125            # fraction of blocks per super-block
    mode: str = "block"           # rand | block | none
    seed: int = 0

    def epoch_order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        idx = np.arange(self.num_items)
        if self.mode == "none":
            return idx
        if self.mode == "rand":
            return rng.permutation(idx)
        n_blocks = (self.num_items + self.block_size - 1) // self.block_size
        return block_shuffle(np.array_split(idx, n_blocks), self.mix, rng)


class SyntheticTokens:
    """Deterministic synthetic LM corpus: Zipfian tokens with local
    structure (so loss decreases measurably in examples/tests)."""

    def __init__(self, vocab: int, num_docs: int = 4096, doc_len: int = 1024,
                 seed: int = 0):
        self.vocab = vocab
        self.num_docs = num_docs
        self.doc_len = doc_len
        self.seed = seed

    def doc(self, i: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, i))
        base = rng.zipf(1.5, self.doc_len).astype(np.int64)
        tok = base % (self.vocab - 2) + 1
        # inject a repeated local pattern -> learnable bigram structure
        tok[1::2] = (tok[::2][: len(tok[1::2])] * 7 + 3) % (self.vocab - 2) + 1
        return tok


class LMStream:
    """Batches of (tokens, labels) with block-shuffled doc order and a
    resumable cursor."""

    def __init__(self, corpus: SyntheticTokens, batch: int, seq: int,
                 shuffler: BlockShuffler = None, cursor: Cursor = None):
        self.corpus = corpus
        self.batch = batch
        self.seq = seq
        self.shuffler = shuffler or BlockShuffler(corpus.num_docs, 64)
        self.cursor = cursor or Cursor()

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            order = self.shuffler.epoch_order(self.cursor.epoch)
            while self.cursor.pos + self.batch <= len(order):
                ids = order[self.cursor.pos:self.cursor.pos + self.batch]
                toks = np.stack([
                    np.resize(self.corpus.doc(i), self.seq + 1)
                    for i in ids])
                self.cursor.pos += self.batch
                yield toks[:, :-1].astype(np.int32), \
                    toks[:, 1:].astype(np.int32)
            self.cursor.epoch += 1
            self.cursor.pos = 0
