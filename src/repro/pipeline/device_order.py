"""Jitted on-device mirror of `repro.batching.order` (epoch root orders).

`batching/order.py` computes every policy's per-epoch root permutation as
a closed-form function of two uint32 epoch words: murmur-mix a position
counter with the words, stable-argsort the keys. This module runs the SAME
computation under `jax.jit`, so the per-epoch root order lives on device
and never crosses the host boundary per batch.

Bit-match contract: for every registered policy
(rand/norand/comm_rand/clustergcn/labor),

    device_epoch_order(OrderSpec.for_policy(graph, policy),
                       epoch_words_for(seed, epoch))
 ==  policy.epoch_order(graph.train_ids, graph.communities,
                        np.random.default_rng((seed, epoch)))

element for element. Both sides hash identical uint32 counters with
identical constants (imported from `batching.order` — one source of
truth) and break ties with stable argsorts over identical input layouts,
so equality is structural, not statistical. CI re-checks it for all five
policies on every run (`benchmarks/pipeline_bench.py`).

The static layout (community-sorted ids, block boundaries, community-of-
train) is precomputed ONCE per (graph, policy) in `OrderSpec`; per epoch
only the two key words change.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.batching.order import (MIX_A, MIX_B, SALT_BLOCK, SALT_ELEM,
                                  SALT_PERM, community_groups, epoch_words)


def epoch_words_for(seed: int, epoch: int) -> np.ndarray:
    """The two uint32 epoch words `BatchStream.root_batches` consumes:
    the first (and only) Generator draw of `default_rng((seed, epoch))`."""
    return epoch_words(np.random.default_rng((seed, epoch)))


def _hash_u32(idx, words, salt: int):
    """jnp twin of `batching.order.hash_u32` — op-for-op identical uint32
    wraparound arithmetic (`salt` is a trace-time constant)."""
    x = idx.astype(jnp.uint32)
    for w in (words[0].astype(jnp.uint32) ^ jnp.uint32(salt),
              words[1].astype(jnp.uint32)):
        x = x ^ w
        x = x * jnp.uint32(MIX_A)
        x = x ^ (x >> jnp.uint32(13))
        x = x * jnp.uint32(MIX_B)
        x = x ^ (x >> jnp.uint32(16))
    return x


@jax.jit
def _order_perm(words, ids):
    """rand / labor roots: ids under a hash-keyed whole-set permutation."""
    keys = _hash_u32(jnp.arange(ids.shape[0]), words, SALT_PERM)
    return ids[jnp.argsort(keys, stable=True)]


@functools.partial(jax.jit, static_argnames=("m",))
def _order_comm_rand(words, ids, sizes, block_of, off_in_block, m: int):
    """comm_rand: `block_shuffle_perm` verbatim, vectorized on device.
    `ids` is the community-sorted concatenation (block 0 first); `m` is
    the static super-block size max(1, round(mix * n_blocks))."""
    n = sizes.shape[0]
    bkey = _hash_u32(jnp.arange(n), words, SALT_BLOCK)
    border = jnp.argsort(bkey, stable=True)
    rank = jnp.zeros(n, jnp.int32).at[border].set(
        jnp.arange(n, dtype=jnp.int32))
    starts_shuf = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(sizes[border])[:-1]])
    elem_rank = rank[block_of]
    gpos = starts_shuf[elem_rank] + off_in_block
    sb = elem_rank // m
    idx = jnp.argsort(_hash_u32(gpos, words, SALT_ELEM), stable=True)
    idx = idx[jnp.argsort(sb[idx], stable=True)]
    return ids[idx]


@functools.partial(jax.jit, static_argnames=("n_comm", "ppb"))
def _order_clustergcn(words, ids, comm_of, n_comm: int, ppb: int):
    """clustergcn: hash-permute community ids, merge consecutive groups of
    `ppb` into unions, list train roots by (union, original position) —
    the device twin of `ClusterGCNPolicy._grouped`'s bucketed pass."""
    ckey = _hash_u32(jnp.arange(n_comm), words, SALT_PERM)
    corder = jnp.argsort(ckey, stable=True)
    rank_c = jnp.zeros(n_comm, jnp.int32).at[corder].set(
        jnp.arange(n_comm, dtype=jnp.int32))
    union = rank_c[comm_of] // ppb
    return ids[jnp.argsort(union, stable=True)]


@dataclass(frozen=True)
class OrderSpec:
    """Static per-(graph, policy) layout for the device order programs.

    `ids` is the reference concatenation the per-epoch permutation is
    applied to: train_ids as-is for rand/labor/clustergcn, the community-
    sorted concatenation for norand/comm_rand. Everything here is computed
    once at stream construction; per epoch only two uint32 words move.
    """
    kind: str                               # rand|norand|comm_rand|clustergcn
    ids: jnp.ndarray                        # (T,) int32
    sizes: Optional[jnp.ndarray] = None     # (n_blocks,) int32   [comm_rand]
    block_of: Optional[jnp.ndarray] = None  # (T,) int32          [comm_rand]
    off_in_block: Optional[jnp.ndarray] = None  # (T,) int32      [comm_rand]
    m: int = 1                              # super-block size    [comm_rand]
    comm_of: Optional[jnp.ndarray] = None   # (T,) int32          [clustergcn]
    n_comm: int = 0                         # static              [clustergcn]
    ppb: int = 1                            # parts_per_batch     [clustergcn]

    @property
    def num_train(self) -> int:
        return int(self.ids.shape[0])

    @staticmethod
    def for_policy(graph, policy) -> "OrderSpec":
        """Build the static layout for a registered policy. Raises
        NotImplementedError for policies without a device order program
        (the builder falls back to the host path for those)."""
        name = getattr(policy, "name", None)
        if name not in ("rand", "labor", "norand", "comm_rand",
                        "clustergcn"):
            raise NotImplementedError(
                f"no device order program for policy {name!r}")
        train = np.asarray(graph.train_ids)
        if name in ("rand", "labor"):
            return OrderSpec("rand", jnp.asarray(train, jnp.int32))
        if name in ("norand", "comm_rand"):
            groups = community_groups(train, graph.communities)
            flat = np.concatenate(groups)
            if name == "norand":
                return OrderSpec("norand", jnp.asarray(flat, jnp.int32))
            sizes = np.fromiter((len(g) for g in groups), np.int64,
                                count=len(groups))
            block_of = np.repeat(np.arange(len(groups)), sizes)
            starts = np.zeros(len(groups), np.int64)
            np.cumsum(sizes[:-1], out=starts[1:])
            off = np.arange(len(flat)) - starts[block_of]
            return OrderSpec(
                "comm_rand", jnp.asarray(flat, jnp.int32),
                sizes=jnp.asarray(sizes, jnp.int32),
                block_of=jnp.asarray(block_of, jnp.int32),
                off_in_block=jnp.asarray(off, jnp.int32),
                m=max(1, int(round(policy.mix * len(groups)))))
        if name == "clustergcn":
            n_comm = int(graph.communities.max()) + 1
            return OrderSpec(
                "clustergcn", jnp.asarray(train, jnp.int32),
                comm_of=jnp.asarray(graph.communities[train], jnp.int32),
                n_comm=n_comm, ppb=int(policy.parts_per_batch))
        raise AssertionError(name)      # unreachable: gated above


def device_epoch_order(spec: OrderSpec, words) -> jnp.ndarray:
    """(T,) int32 root ids for one epoch, computed on device. `words` is
    `epoch_words_for(seed, epoch)` (host numpy or device array)."""
    words = jnp.asarray(words, jnp.uint32)
    if spec.kind == "norand":
        return spec.ids
    if spec.kind == "rand":
        return _order_perm(words, spec.ids)
    if spec.kind == "comm_rand":
        return _order_comm_rand(words, spec.ids, spec.sizes, spec.block_of,
                                spec.off_in_block, spec.m)
    if spec.kind == "clustergcn":
        return _order_clustergcn(words, spec.ids, spec.comm_of,
                                 spec.n_comm, spec.ppb)
    raise ValueError(spec.kind)


def order_bitmatch(graph, policy, seed: int = 0, epochs=(0, 1)) -> bool:
    """True iff the device order equals the numpy policy path bit-for-bit
    for every epoch in `epochs` — the CI gate for the mirror contract."""
    spec = OrderSpec.for_policy(graph, policy)
    for epoch in epochs:
        want = policy.epoch_order(graph.train_ids, graph.communities,
                                  np.random.default_rng((seed, epoch)))
        got = np.asarray(device_epoch_order(
            spec, epoch_words_for(seed, epoch)))
        if not np.array_equal(got.astype(np.int64), np.asarray(want)):
            return False
    return True
