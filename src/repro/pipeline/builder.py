"""`DeviceBatchBuilder`: fused on-device batch construction.

The synchronous `BatchStream` path does real host work per batch: slice a
numpy root array, ship it host->device, then dispatch the jitted
sample/dedup builder. This builder removes the per-batch host leg
entirely:

  * the EPOCH root order is computed on device (`device_order`) and stays
    resident for the whole epoch as one padded (num_batches * B,) buffer
    — exactly one order computation per epoch, zero per-batch transfers
    (the previous epoch's buffer is donated to the refresh off-CPU);
  * one fused jit derives the batch PRNG keys from (seed, epoch, pos),
    slices batch `pos`'s roots out of the resident order
    (`lax.dynamic_slice`), and runs the SAME `_build_batch_impl` body the
    stream uses — so the produced `MiniBatch` is bit-exact against
    `BatchStream.build` for the same cursor;
  * shared-randomness sampler state (LABOR's per-node ranks) is hoisted
    to one pass per EPOCH (`epoch_ranks`) and threaded into every build
    of that epoch.

Policies without a device order program fall back to the numpy
`epoch_order` once per epoch (still one transfer per epoch, not per
batch).

`stage_times` is the shared per-stage microbenchmark (roots prep /
neighbor sample / dedup+remap) used by `benchmarks/pipeline_bench.py` and
`benchmarks/sampler_bench.py`'s `build_breakdown_us` columns.
"""
from __future__ import annotations

import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sampling
from repro.batching.policy import as_policy
from repro.core import minibatch as mb
from repro.graphs.csr import DeviceGraph, Graph
from repro.obs import trace as obs_trace
from repro.pipeline.device_order import (OrderSpec, device_epoch_order,
                                         epoch_words_for)
from repro.resilience import faults


@functools.partial(jax.jit, static_argnames=("P",))
def _pad_fresh(order, P: int):
    """(P,) int32 order buffer, -1 padded past the true order length."""
    return jnp.full((P,), -1, jnp.int32).at[:order.shape[0]].set(order)


@functools.partial(jax.jit, donate_argnums=(1,))
def _pad_into(order, scratch):
    """Same as `_pad_fresh` but recycles the previous epoch's buffer via
    donation — the refresh writes in place instead of allocating (used
    off-CPU only; CPU donation is a no-op that logs warnings)."""
    return scratch.at[:].set(-1).at[:order.shape[0]].set(order)


@functools.partial(jax.jit,
                   static_argnames=("B", "fanouts", "caps", "sampler"))
def _fused_build(seed_key, epoch, pos, g, order_pad, labels_all,
                 shared_ctx, B: int, fanouts, caps, sampler):
    """Key derivation + root slice + build, one dispatch. `epoch`/`pos`
    ride in as int32 scalars (traced, no retrace per batch); the keys are
    the stream's exact derivation — fold_in(fold_in(key(seed), epoch),
    pos) — computed on device."""
    ek = jax.random.fold_in(seed_key, epoch)
    bk = jax.random.fold_in(ek, pos)
    roots = jax.lax.dynamic_slice(order_pad, (pos * B,), (B,))
    return mb._build_batch_impl(bk, ek, g, roots, labels_all,
                                fanouts, caps, sampler, shared_ctx)


class DeviceBatchBuilder:
    """Per-(epoch, pos) `MiniBatch` factory with a device-resident epoch
    order. Mirrors `BatchStream`'s deterministic derivations exactly:
    `build(epoch, pos)` == `stream.build(root_batches(epoch)[pos], epoch,
    pos)` bit for bit."""

    def __init__(self, graph: Graph, policy, batch_size: int, fanouts,
                 caps, *, seed: int = 0, drop_last: bool = False,
                 sampler=None, mode: str = "sample",
                 device_graph: Optional[DeviceGraph] = None,
                 labels=None):
        self.graph = graph
        self.policy = as_policy(policy)
        self.batch_size = int(batch_size)
        self.fanouts = tuple(fanouts)
        self.caps = tuple(caps)
        self.seed = seed
        self.drop_last = drop_last
        self.sampler = sampling.resolve(
            sampler, mode, lambda: sampling.for_policy(self.policy))
        self.g = device_graph or DeviceGraph.from_graph(graph)
        self.labels = labels if labels is not None \
            else jnp.asarray(graph.labels)
        T = len(graph.train_ids)
        self.num_batches = T // self.batch_size if drop_last \
            else -(-T // self.batch_size)
        self.padded_len = self.num_batches * self.batch_size
        try:
            self.spec = OrderSpec.for_policy(graph, self.policy)
        except NotImplementedError:
            self.spec = None            # host numpy order, once per epoch
        # donation recycles the order buffer only off-CPU (CPU donation
        # is rejected by XLA and logs a warning per dispatch)
        self._donate = jax.default_backend() != "cpu"
        self._seed_key = jax.random.key(seed)
        self._order_cache = (-1, None)
        self._ranks_cache = (-1, None)

    @classmethod
    def from_stream(cls, stream) -> "DeviceBatchBuilder":
        """A builder sharing a `BatchStream`'s graph/sampler/derivations
        (same device graph + labels arrays — no duplicate residency)."""
        return cls(stream.graph, stream.policy, stream.batch_size,
                   stream.fanouts, stream.caps, seed=stream.seed,
                   drop_last=stream.drop_last, sampler=stream.sampler,
                   device_graph=stream.g, labels=stream.labels)

    # -- deterministic derivations (identical to BatchStream) ---------------
    def epoch_key(self, epoch: int):
        return jax.random.fold_in(self._seed_key, epoch)

    def batch_key(self, epoch: int, pos: int):
        return jax.random.fold_in(self.epoch_key(epoch), pos)

    # -- per-epoch device state ---------------------------------------------
    def epoch_roots(self, epoch: int) -> jnp.ndarray:
        """The (num_batches * B,) device-resident root order for `epoch`,
        -1 padded (cached; recomputed once per epoch)."""
        if self._order_cache[0] == epoch:
            return self._order_cache[1]
        with obs_trace.span("epoch_order", cat="build", epoch=epoch):
            return self._epoch_roots_fresh(epoch)

    def _epoch_roots_fresh(self, epoch: int) -> jnp.ndarray:
        if self.spec is not None:
            order = device_epoch_order(
                self.spec, epoch_words_for(self.seed, epoch))
        else:
            rng = np.random.default_rng((self.seed, epoch))
            order = jnp.asarray(self.policy.epoch_order(
                self.graph.train_ids, self.graph.communities, rng),
                jnp.int32)
        if order.shape[0] > self.padded_len:      # drop_last truncation
            order = order[:self.padded_len]
        prev = self._order_cache[1]
        if self._donate and prev is not None:
            pad = _pad_into(order, prev)
        else:
            pad = _pad_fresh(order, self.padded_len)
        self._order_cache = (epoch, pad)
        return pad

    def epoch_ranks(self, epoch: int):
        """Shared-randomness sampler state for `epoch`, computed once and
        threaded into every build of the epoch (None for samplers without
        one)."""
        if self._ranks_cache[0] != epoch:
            self._ranks_cache = (epoch, mb.sampler_epoch_ctx(
                self.sampler, self.epoch_key(epoch), self.g))
        return self._ranks_cache[1]

    # -- the fused build ----------------------------------------------------
    def build(self, epoch: int, pos: int) -> mb.MiniBatch:
        """MiniBatch for cursor (epoch, pos) — one jit dispatch, no
        per-batch host->device transfer beyond two int32 scalars."""
        if not 0 <= pos < self.num_batches:
            raise IndexError(
                f"pos {pos} out of range for {self.num_batches} batches")
        # chaos site (repro.resilience): an armed plan makes this build
        # raise InjectedFault — in the async pipeline that kills the
        # producer thread, which the consumer watchdog must absorb by
        # restarting from the same cursor (bit-exact, builds are pure)
        faults.maybe_raise("batch_build", epoch=epoch, pos=pos)
        with obs_trace.span("batch_build", cat="build",
                            epoch=epoch, pos=pos):
            return _fused_build(
                self._seed_key, jnp.asarray(epoch, jnp.int32),
                jnp.asarray(pos, jnp.int32), self.g,
                self.epoch_roots(epoch), self.labels,
                self.epoch_ranks(epoch), self.batch_size,
                self.fanouts, self.caps, self.sampler)


# ---------------------------------------------------------------------------
# per-stage microbenchmark (roots / sample / dedup)
# ---------------------------------------------------------------------------
def _time_us(fn, *args, iters: int = 10) -> float:
    # analysis: allow[no-host-sync-in-hot-path] -- microbenchmark warmup: compiles + drains before timing, never on the training path
    jax.block_until_ready(fn(*args))          # compile + warm
    best = float("inf")
    for _ in range(iters):
        # analysis: allow[no-wall-clock] -- stage-timing instrumentation; timings are reported, never fed back into batch construction
        t0 = time.perf_counter()
        # analysis: allow[no-host-sync-in-hot-path] -- benchmark drain: the measurement IS the sync
        jax.block_until_ready(fn(*args))
        # analysis: allow[no-wall-clock] -- stage-timing instrumentation; timings are reported, never fed back into batch construction
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def stage_times(g: DeviceGraph, roots, labels_all, fanouts, caps, sampler,
                *, key=None, epoch_key=None, iters: int = 10) -> dict:
    """Best-of-`iters` device time (µs) per build stage, on realized
    levels of one representative batch:

      roots_us    root mask + sort (level-0 prep)
      sample_us   all hops' neighbor sampling
      dedup_us    concat + static-size unique + position remap per hop

    The stages are timed as separate jits over the SAME intermediates the
    fused builder produces, so the split is apples-to-apples with the
    whole-build numbers in `sampler_sweep/*`.
    """
    fanouts, caps = tuple(fanouts), tuple(caps)
    sampler = sampling.resolve(sampler)
    key = jax.random.key(0) if key is None else key
    epoch_key = key if epoch_key is None else epoch_key
    N = g.num_nodes
    roots = jnp.asarray(roots, jnp.int32)
    shared = mb.sampler_epoch_ctx(sampler, epoch_key, g)

    @jax.jit
    def roots_fn(r):
        m = r >= 0
        return jnp.sort(jnp.where(m, r, N).astype(jnp.int32))

    @jax.jit
    def sample_fn(k, ek, levels):
        keys = jax.random.split(k, len(fanouts))
        out = []
        for h, fan in enumerate(fanouts):
            k_h = ek if sampler.shared_randomness else keys[h]
            if shared is not None:
                out.append(sampler.sample(k_h, g, levels[h], fan,
                                          ranks=shared))
            else:
                out.append(sampler.sample(k_h, g, levels[h], fan))
        return out

    @jax.jit
    def dedup_fn(levels, srcs):
        out = []
        for h, (fan, cap) in enumerate(zip(fanouts, caps)):
            prev = levels[h]
            s = srcs[h][0].reshape(-1)
            nxt = jnp.unique(jnp.concatenate([prev, s]), size=cap,
                             fill_value=N).astype(jnp.int32)
            out.append((nxt,) + mb._positions(nxt, prev)
                       + mb._positions(nxt, s))
        return out

    batch = mb._build_batch(key, epoch_key, g, roots, labels_all,
                            fanouts, caps, sampler)
    levels = tuple(jax.block_until_ready(batch.levels))[:-1]
    srcs = jax.block_until_ready(sample_fn(key, epoch_key, levels))
    return {
        "roots_us": _time_us(roots_fn, roots, iters=iters),
        "sample_us": _time_us(sample_fn, key, epoch_key, levels,
                              iters=iters),
        "dedup_us": _time_us(dedup_fn, levels, srcs, iters=iters),
    }
