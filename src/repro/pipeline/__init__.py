"""`repro.pipeline` — end-to-end on-device batch construction.

The host batch-build path (numpy epoch order -> per-batch root slice ->
host->device transfer -> jitted sample/dedup) starves the accelerator:
`BENCH_kernels.json` `sampler_sweep/*` showed 46-232 ms per batch of host
work against a ~3 ms jitted train step. This subsystem moves the whole
root-ordering -> neighbor-sample -> dedup -> cap path onto the device and
overlaps the build of batch k+1 with train step k:

  device_order   jitted mirror of `batching/order.py`'s hash-keyed
                 block-shuffle — per-epoch root permutations computed on
                 device, bit-matched to the numpy path for every
                 registered policy (rand/norand/comm_rand/clustergcn/
                 labor)
  builder        `DeviceBatchBuilder`: the epoch root order stays
                 resident on device and one fused jit slices the roots
                 for batch (epoch, pos) and runs the shared
                 `_build_batch` body — no per-batch host->device root
                 transfer, LABOR's shared ranks hoisted to one pass per
                 epoch
  prefetch       `AsyncBatchStream`: a depth-k (default 2) dispatch
                 queue on a background thread, drop-in compatible with
                 `BatchStream` (same `Cursor` checkpoint/resume
                 semantics, bit-exact batch sequence vs the synchronous
                 stream)

`GNNTrainer(pipeline="async")` and `examples/train_gnn_commrand.py
--pipeline async` select it; `benchmarks/pipeline_bench.py` measures
batches/sec, the per-stage build breakdown, and the device-idle fraction
for sync vs async into `BENCH_kernels.json` `pipeline/*`.
"""
from repro.pipeline.builder import DeviceBatchBuilder, stage_times
from repro.pipeline.device_order import (OrderSpec, device_epoch_order,
                                         order_bitmatch)
from repro.pipeline.prefetch import AsyncBatchStream

__all__ = [
    "AsyncBatchStream", "DeviceBatchBuilder", "OrderSpec",
    "device_epoch_order", "order_bitmatch", "stage_times",
]
