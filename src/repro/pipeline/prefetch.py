"""`AsyncBatchStream`: depth-k background batch prefetching.

A drop-in `BatchStream` whose batches are produced by a background
producer thread through the fused `DeviceBatchBuilder` (device-resident
epoch order, one jit dispatch per batch). A bounded queue of depth `k`
(default 2 — double buffering) applies backpressure: while the trainer
consumes step i the producer has already dispatched builds i+1..i+k, so
sample/dedup for the next batches overlaps the current train step.

    consumer   | step i        | step i+1      | step i+2
    producer   | build i+1, i+2| build i+3     | ...

The GIL does not serialize the useful work: the producer thread spends
its time inside jit dispatch + XLA, which release the GIL, and jax
dispatch is itself asynchronous.

Determinism contract: identical to `BatchStream`. The producer walks the
same (epoch, pos) cursor arithmetic and the builder derives every key
from (seed, epoch, pos), so the delivered batch SEQUENCE is bit-exact
against the synchronous stream — including after an external cursor
reset (`Cursor.from_state` resume): `_take` detects that the requested
cursor is not what the producer is about to deliver and restarts the
producer from the restored cursor, discarding in-flight work.
"""
from __future__ import annotations

import queue
import threading

from repro.batching.stream import BatchStream
from repro.core import minibatch as mb
from repro.pipeline.builder import DeviceBatchBuilder

_POLL_S = 0.05          # producer put/consumer get poll for shutdown checks


class AsyncBatchStream(BatchStream):
    """`BatchStream` with a depth-k background dispatch queue.

    Same constructor plus `depth` (queue size, default 2). Checkpointing
    is unchanged: `cursor.state()` / assigning a restored `Cursor` works
    mid-epoch with builds in flight.
    """

    def __init__(self, *args, depth: int = 2, **kwargs):
        # the base class's single-slot dispatch is superseded by the queue
        kwargs.setdefault("dispatch_ahead", False)
        super().__init__(*args, **kwargs)
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.depth = depth
        self.builder = DeviceBatchBuilder.from_stream(self)
        self._queue = None          # queue.Queue of (epoch, pos, batch)
        self._thread = None
        self._gen = 0               # bumped on restart; stale producers exit
        self._stop = threading.Event()
        self._next_out = None       # (epoch, pos) at the queue's head

    # -- producer -----------------------------------------------------------
    def _advance(self, epoch: int, pos: int):
        """Cursor arithmetic of `epoch()`: next (epoch, pos) delivered."""
        if pos + 1 < self.num_batches(epoch):
            return epoch, pos + 1
        return epoch + 1, 0

    def _produce(self, epoch: int, pos: int, gen: int, q) -> None:
        try:
            while not self._stop.is_set() and gen == self._gen:
                if self.num_batches(epoch) == 0:
                    return          # consumer raises; nothing to build
                batch = self.builder.build(epoch, pos)
                while gen == self._gen and not self._stop.is_set():
                    try:
                        q.put((epoch, pos, batch), timeout=_POLL_S)
                        break
                    except queue.Full:
                        continue
                epoch, pos = self._advance(epoch, pos)
        except BaseException as exc:    # surface build errors to consumer
            try:
                q.put(("error", exc, None), timeout=1.0)
            except queue.Full:
                pass

    def _restart(self, epoch: int, pos: int) -> None:
        self._gen += 1              # in-flight producer drains out and exits
        self._queue = queue.Queue(maxsize=self.depth)
        self._next_out = (epoch, pos)
        self._thread = threading.Thread(
            target=self._produce, args=(epoch, pos, self._gen, self._queue),
            name=f"AsyncBatchStream-{id(self):x}", daemon=True)
        self._thread.start()

    # -- consumer -----------------------------------------------------------
    def _take(self, epoch: int, pos: int) -> mb.MiniBatch:
        if self._thread is None or not self._thread.is_alive() \
                or self._next_out != (epoch, pos):
            # first use, or an external cursor reset (checkpoint resume):
            # drop in-flight work and realign the producer
            self._restart(epoch, pos)
        q = self._queue
        while True:
            try:
                item = q.get(timeout=_POLL_S)
            except queue.Empty:
                if self._thread is None or not self._thread.is_alive():
                    raise RuntimeError(
                        "AsyncBatchStream producer died without output")
                continue
            if item[0] == "error":
                self.close()
                raise item[1]
            e, p, batch = item
            if (e, p) != (epoch, pos):      # stale pre-restart leftover
                continue
            self._next_out = self._advance(epoch, pos)
            return batch

    def _dispatch_ahead(self, epoch: int, pos: int) -> None:
        pass                        # the queue IS the lookahead

    def close(self) -> None:
        """Stop the producer and drop queued work (idempotent)."""
        self._gen += 1
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None and t.is_alive():
            q = self._queue
            while t.is_alive():     # unblock a producer stuck on put()
                try:
                    q.get_nowait()
                except queue.Empty:
                    pass
                t.join(timeout=_POLL_S)
        self._queue = None
        self._next_out = None
        self._stop = threading.Event()   # close() then reuse => restart

    def __del__(self):
        try:
            self._stop.set()
            self._gen += 1
        except Exception:
            pass
