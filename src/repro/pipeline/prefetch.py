"""`AsyncBatchStream`: depth-k background batch prefetching + watchdog.

A drop-in `BatchStream` whose batches are produced by a background
producer thread through the fused `DeviceBatchBuilder` (device-resident
epoch order, one jit dispatch per batch). A bounded queue of depth `k`
(default 2 — double buffering) applies backpressure: while the trainer
consumes step i the producer has already dispatched builds i+1..i+k, so
sample/dedup for the next batches overlaps the current train step.

    consumer   | step i        | step i+1      | step i+2
    producer   | build i+1, i+2| build i+3     | ...

The GIL does not serialize the useful work: the producer thread spends
its time inside jit dispatch + XLA, which release the GIL, and jax
dispatch is itself asynchronous.

Determinism contract: identical to `BatchStream`. The producer walks the
same (epoch, pos) cursor arithmetic and the builder derives every key
from (seed, epoch, pos), so the delivered batch SEQUENCE is bit-exact
against the synchronous stream — including after an external cursor
reset (`Cursor.from_state` resume): `_take` detects that the requested
cursor is not what the producer is about to deliver and restarts the
producer from the restored cursor, discarding in-flight work.

Watchdog: the producer heartbeats (`_beat`) at every loop turn and while
blocked on a full queue; the consumer, whenever its queue wait comes up
empty, checks for a DEAD producer (thread exited — the real exception is
stashed on `_exc`) or a STALLED one (no heartbeat for `stall_timeout_s`).
Either way it restarts the producer from the cursor it is waiting on,
with exponential backoff (`restart_backoff_s * 2^attempt`) and a bounded
consecutive budget (`max_restarts`); past the budget the REAL producer
error (with its original traceback) is raised, not a generic wrapper.
The restart is safe precisely because builds are a pure function of the
cursor (PR 6): rebuilding (epoch, pos) yields the same batch bit for
bit, so recovery never perturbs the delivered sequence. Restarts are
counted on `self.restarts` and, when a `train.monitor.ResilienceMeter`
is attached (`meter=`), metered as `producer_restarts` events.

Heartbeats pause during a long jitted build (first-call compilation
included), so `stall_timeout_s` defaults high (60 s); latency-sensitive
consumers should `prime()` once (compile everything synchronously) and
then lower the timeout. Fault injection (`repro.resilience`): the
`producer_hang` site stalls the producer heartbeat-less until a
generation bump, and `batch_build` faults raised inside
`DeviceBatchBuilder.build` surface through the dead-producer path —
both recover through this watchdog.
"""
from __future__ import annotations

import queue
import threading
import time

from repro.batching.stream import BatchStream
from repro.core import minibatch as mb
from repro.obs import trace as obs_trace
from repro.pipeline.builder import DeviceBatchBuilder
from repro.resilience import faults

_POLL_S = 0.05          # producer put/consumer get poll for shutdown checks


class AsyncBatchStream(BatchStream):
    """`BatchStream` with a depth-k background dispatch queue.

    Same constructor plus `depth` (queue size, default 2) and the
    watchdog knobs (`stall_timeout_s`, `max_restarts`,
    `restart_backoff_s`, `meter`). Checkpointing is unchanged:
    `cursor.state()` / assigning a restored `Cursor` works mid-epoch
    with builds in flight.
    """

    def __init__(self, *args, depth: int = 2, stall_timeout_s: float = 60.0,
                 max_restarts: int = 3, restart_backoff_s: float = 0.05,
                 meter=None, **kwargs):
        # the base class's single-slot dispatch is superseded by the queue
        kwargs.setdefault("dispatch_ahead", False)
        super().__init__(*args, **kwargs)
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.depth = depth
        self.stall_timeout_s = stall_timeout_s
        self.max_restarts = max_restarts
        self.restart_backoff_s = restart_backoff_s
        self.meter = meter          # optional ResilienceMeter
        self.restarts = 0           # lifetime watchdog restarts
        self.builder = DeviceBatchBuilder.from_stream(self)
        self._queue = None          # queue.Queue of (epoch, pos, batch)
        self._thread = None
        self._gen = 0               # bumped on restart; stale producers exit
        self._stop = threading.Event()
        self._next_out = None       # (epoch, pos) at the queue's head
        self._beat = None           # monotonic time of last producer beat
        self._exc = None            # stashed REAL producer exception
        self._consec_restarts = 0   # watchdog budget, reset on delivery

    # -- producer -----------------------------------------------------------
    def _advance(self, epoch: int, pos: int):
        """Cursor arithmetic of `epoch()`: next (epoch, pos) delivered."""
        if pos + 1 < self.num_batches(epoch):
            return epoch, pos + 1
        return epoch + 1, 0

    def _produce(self, epoch: int, pos: int, gen: int, q) -> None:
        try:
            while not self._stop.is_set() and gen == self._gen:
                # analysis: allow[no-wall-clock] -- watchdog heartbeat: liveness only, never influences delivered batch data
                self._beat = time.monotonic()
                if self.num_batches(epoch) == 0:
                    return          # consumer raises; nothing to build
                if faults.fire("producer_hang", epoch=epoch,
                               pos=pos) is not None:
                    # chaos site: stop heartbeating and producing until a
                    # generation bump (watchdog restart or close) ends us
                    while gen == self._gen and not self._stop.is_set():
                        time.sleep(_POLL_S)
                    return
                # cat="producer": these spans live on the producer thread;
                # their wall-clock intersection with consumer cat="step"
                # spans IS the measured prefetch overlap (obs.report)
                with obs_trace.span("producer_build", cat="producer",
                                    epoch=epoch, pos=pos):
                    batch = self.builder.build(epoch, pos)
                with obs_trace.span("queue_put_wait", cat="wait",
                                    epoch=epoch, pos=pos):
                    while gen == self._gen and not self._stop.is_set():
                        # analysis: allow[no-wall-clock] -- watchdog heartbeat: liveness only, never influences delivered batch data
                        self._beat = time.monotonic()  # full queue: healthy
                        try:
                            q.put((epoch, pos, batch), timeout=_POLL_S)
                            break
                        except queue.Full:
                            continue
                epoch, pos = self._advance(epoch, pos)
        except BaseException as exc:    # surface build errors to consumer
            # stash the real exception (with traceback) BEFORE attempting
            # the queue handoff: if the error q.put times out on a full
            # queue, _take still re-raises the true error instead of a
            # generic "producer died" RuntimeError
            self._exc = exc
            try:
                q.put(("error", exc, None), timeout=1.0)
            except queue.Full:
                pass

    def _restart(self, epoch: int, pos: int) -> None:
        self._gen += 1              # in-flight producer drains out and exits
        self._queue = queue.Queue(maxsize=self.depth)
        self._next_out = (epoch, pos)
        # analysis: allow[no-wall-clock] -- watchdog grace period on restart; batches remain pure in (epoch, pos)
        self._beat = time.monotonic()   # fresh grace period
        self._thread = threading.Thread(
            target=self._produce, args=(epoch, pos, self._gen, self._queue),
            name=f"AsyncBatchStream-{id(self):x}", daemon=True)
        self._thread.start()

    # -- consumer + watchdog ------------------------------------------------
    def _stalled(self) -> bool:
        return (self.stall_timeout_s is not None and self._beat is not None
                # analysis: allow[no-wall-clock] -- stall detection compares heartbeats; recovery replays the same cursor bit-exactly
                and time.monotonic() - self._beat > self.stall_timeout_s)

    def _recover(self, epoch: int, pos: int, reason: BaseException) -> None:
        """Watchdog action: restart the producer from the cursor we are
        waiting on — bit-exact, since builds are pure in (epoch, pos) —
        with exponential backoff and a bounded consecutive budget. Past
        the budget, raise the stashed real producer error (original
        traceback) or the stall diagnosis."""
        if self._consec_restarts >= self.max_restarts:
            err = self._exc if self._exc is not None else reason
            self.close()
            raise err
        self._consec_restarts += 1
        self.restarts += 1
        if self.meter is not None:
            self.meter.note("producer_restarts", epoch=epoch, pos=pos,
                            reason=repr(reason))
        time.sleep(self.restart_backoff_s
                   * (2 ** (self._consec_restarts - 1)))
        self._exc = None
        self._restart(epoch, pos)

    def _take(self, epoch: int, pos: int) -> mb.MiniBatch:
        if self._thread is None or self._next_out != (epoch, pos):
            # first use, or an external cursor reset (checkpoint resume):
            # drop in-flight work and realign the producer. A DEAD but
            # still-aligned producer is deliberately NOT handled here —
            # it falls through to the loop below so the restart goes
            # through `_recover` (metered, backed off, budgeted).
            self._restart(epoch, pos)
        # cat="wait": total time the CONSUMER blocked before this batch
        # came off the queue — the "consumer starved" stall site in the
        # analyzer (its mirror, queue_put_wait, is healthy backpressure)
        with obs_trace.span("queue_get_wait", cat="wait",
                            epoch=epoch, pos=pos):
            while True:
                q = self._queue
                try:
                    item = q.get(timeout=_POLL_S)
                except queue.Empty:
                    if self._thread is None or not self._thread.is_alive():
                        self._recover(epoch, pos, self._exc or RuntimeError(
                            "AsyncBatchStream producer died without output"))
                    elif self._stalled():
                        self._recover(epoch, pos, RuntimeError(
                            f"AsyncBatchStream producer heartbeat stalled "
                            f"> {self.stall_timeout_s}s at {(epoch, pos)}"))
                    continue
                if item[0] == "error":
                    self._recover(epoch, pos, item[1])
                    continue
                e, p, batch = item
                if (e, p) != (epoch, pos):  # stale pre-restart leftover
                    continue
                self._consec_restarts = 0   # healthy delivery resets budget
                self._next_out = self._advance(epoch, pos)
                return batch

    def prime(self) -> "AsyncBatchStream":
        """Compile the fused build path synchronously (one throwaway
        build of the cursor batch). Heartbeats pause during jit
        compilation, so latency-sensitive consumers prime once BEFORE
        tightening `stall_timeout_s` — otherwise the watchdog can
        mistake first-call compilation for a hang."""
        c = self.cursor
        if self.num_batches(c.epoch) > 0:
            import jax
            jax.block_until_ready(
                self.builder.build(c.epoch,
                                   min(c.pos,
                                       self.num_batches(c.epoch) - 1)))
        return self

    def _dispatch_ahead(self, epoch: int, pos: int) -> None:
        pass                        # the queue IS the lookahead

    def close(self) -> None:
        """Stop the producer and drop queued work (idempotent)."""
        self._gen += 1
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None and t.is_alive():
            q = self._queue
            while t.is_alive():     # unblock a producer stuck on put()
                try:
                    q.get_nowait()
                except queue.Empty:
                    pass
                t.join(timeout=_POLL_S)
        self._queue = None
        self._next_out = None
        self._beat = None
        self._stop = threading.Event()   # close() then reuse => restart

    def __del__(self):
        try:
            self._stop.set()
            self._gen += 1
        except Exception:
            pass
