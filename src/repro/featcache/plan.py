"""Cache admission planning: which of the N feature rows live on device.

The paper's cache story (§6.5) is about *reuse*: structure-aware batches
revisit the same feature rows across consecutive mini-batches, so a modest
device-resident cache absorbs most of the feature traffic. `CachePlan` is
the static (software-managed) realization: an admission policy scores every
node on the host, the top-`capacity` rows are copied into a compact
`(C, F)` device array, and an `int32[N]` position map (`-1` = miss) routes
each feature read either into the cache or back to the global matrix
(`repro.kernels.gather_cached`).

Admission policies are frozen dataclasses with pure-numpy scoring — the
same registry idiom as `repro.sampling` / `repro.batching.policy` — so
plans are reproducible, diskless, and the device hit counters can be
bit-checked against the numpy mirror (`cache_stats_np`):

    degree_hot        score = degree (classic static GNN feature cache)
    community_freq    score = training mass of the node's community,
                      degree-weighted (structure-aware: COMM-RAND batches
                      hammer whole communities at a time)
    presampled_freq   score = measured access counts over a presampled
                      epoch prefix of the ACTUAL (policy, sampler) stream
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class AdmissionPolicy(Protocol):
    """Protocol every registered admission policy satisfies. `scores` is
    host-side numpy: higher score = cached first. Ties break toward lower
    node id (deterministic plans)."""

    @property
    def name(self) -> str: ...

    def scores(self, graph, ctx: dict) -> np.ndarray:
        """(N,) float64 hotness scores. `ctx` may carry the training
        context ({"policy", "batch_size", "fanouts", "seed"}) for policies
        that presample the access stream."""
        ...

    def describe(self) -> str: ...


_REGISTRY: Dict[str, Callable[..., "AdmissionPolicy"]] = {}


def register_admission(name: str):
    """Register an admission-policy factory under `name`."""
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def make_admission(name: str, **kwargs) -> "AdmissionPolicy":
    if name not in _REGISTRY:
        raise KeyError(f"unknown admission policy {name!r}; "
                       f"registered: {available_admissions()}")
    return _REGISTRY[name](**kwargs)


def available_admissions() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def as_admission(obj) -> "AdmissionPolicy":
    """Normalize an admission name / instance."""
    if isinstance(obj, str):
        return make_admission(obj)
    if hasattr(obj, "scores") and hasattr(obj, "describe"):
        return obj
    raise TypeError(f"not an admission policy: {obj!r}")


# ---------------------------------------------------------------------------
# registered policies
# ---------------------------------------------------------------------------
@register_admission("degree_hot")
@dataclass(frozen=True)
class DegreeHotAdmission:
    """Cache the highest-degree nodes: high-degree rows are sampled as
    neighbors proportionally more often, regardless of batch policy."""

    @property
    def name(self) -> str:
        return "degree_hot"

    def scores(self, graph, ctx: dict) -> np.ndarray:
        return graph.degrees().astype(np.float64)

    def describe(self) -> str:
        return "degree_hot"


@register_admission("community_freq")
@dataclass(frozen=True)
class CommunityFreqAdmission:
    """Cache nodes of training-heavy communities, hottest-degree first.

    Score = (# training roots in the node's community) * (degree + 1):
    community-biased sampling (p -> 1) keeps neighbor expansion inside the
    root's community, so a community's expected access frequency tracks its
    training mass, and within a community the high-degree hubs soak up the
    fanout draws."""

    @property
    def name(self) -> str:
        return "community_freq"

    def scores(self, graph, ctx: dict) -> np.ndarray:
        comm = graph.communities
        n_comm = int(comm.max()) + 1
        mass = np.zeros(n_comm, np.float64)
        np.add.at(mass, comm[graph.train_ids], 1.0)
        return mass[comm] * (graph.degrees().astype(np.float64) + 1.0)

    def describe(self) -> str:
        return "community_freq"


@register_admission("presampled_freq")
@dataclass(frozen=True)
class PresampledFreqAdmission:
    """Cache the empirically hottest rows: replay `n_batches` batches of
    the ACTUAL (policy, sampler) access stream on the host (the same numpy
    builder caps calibration uses) and score nodes by access count. The
    strongest static policy — it sees exactly the distribution the cache
    will serve — at the cost of a presampling pass per plan."""
    n_batches: int = 16

    @property
    def name(self) -> str:
        return "presampled_freq"

    def scores(self, graph, ctx: dict) -> np.ndarray:
        from repro.featcache.sim import policy_access_stream
        policy = ctx.get("policy")
        if policy is None:
            raise ValueError("presampled_freq admission needs ctx['policy'] "
                             "(the BatchPolicy whose stream it presamples)")
        stream = policy_access_stream(
            graph, policy, ctx.get("batch_size", 512),
            ctx.get("fanouts", (10, 10)), n_batches=self.n_batches,
            seed=ctx.get("seed", 0))
        counts = np.zeros(graph.num_nodes, np.float64)
        for ids in stream:
            np.add.at(counts, np.asarray(ids), 1.0)
        return counts

    def describe(self) -> str:
        return f"presampled_freq(n={self.n_batches})"


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["cache", "pos"], meta_fields=["capacity", "policy"])
@dataclass
class CachePlan:
    """Device-resident static feature cache.

    cache: (C, F) float32 — exact copies of the admitted feature rows, so
           serving a hit is bit-identical to reading the global matrix.
    pos:   (N,) int32 — cache position of node i, or -1 (miss).
    capacity / policy: static metadata (jit-hashable)."""
    cache: jnp.ndarray
    pos: jnp.ndarray
    capacity: int
    policy: str

    def cached_ids(self) -> np.ndarray:
        """(C,) node ids resident in the cache, in cache-row order."""
        pos = np.asarray(self.pos)
        ids = np.where(pos >= 0)[0]
        return ids[np.argsort(pos[ids])]

    def to_dynamic(self):
        """Promote this static plan to CLOCK admission state
        (`repro.featcache.dynamic.DynamicCacheState`): same residency,
        clear reference bits, zeroed accumulators, hand at slot 0."""
        from repro.featcache.dynamic import from_plan
        return from_plan(self)

    def describe(self) -> str:
        return f"{self.policy}@C={self.capacity}"


def select_rows(scores: np.ndarray, capacity: int) -> np.ndarray:
    """Top-`capacity` node ids by score, ties toward lower id (sorted by
    id for locality of the cache array itself)."""
    C = min(int(capacity), len(scores))
    # lexsort: primary -scores, secondary node id (ascending)
    order = np.lexsort((np.arange(len(scores)), -scores))[:C]
    return np.sort(order)


def build_plan(graph, admission="degree_hot", capacity: int = None, *,
               frac: float = 0.2, policy=None, batch_size: int = 512,
               fanouts=(10, 10), seed: int = 0,
               features: np.ndarray = None) -> CachePlan:
    """Score -> select -> materialize the device arrays.

    `capacity` is a row count (defaults to `frac` * N). `policy` (plus
    batch_size/fanouts/seed) is the training context presampling admission
    policies replay."""
    adm = as_admission(admission)
    N = graph.num_nodes
    cap = int(capacity) if capacity is not None else int(N * frac)
    cap = max(cap, 1)           # a (0, F) cache array has no valid gather
    ctx = {"policy": policy, "batch_size": batch_size, "fanouts": fanouts,
           "seed": seed}
    ids = select_rows(adm.scores(graph, ctx), cap)
    pos = np.full(N, -1, np.int32)
    pos[ids] = np.arange(len(ids), dtype=np.int32)
    feats = graph.features if features is None else features
    return CachePlan(
        cache=jnp.asarray(np.asarray(feats)[ids], jnp.float32),
        pos=jnp.asarray(pos),
        capacity=len(ids),
        policy=adm.describe(),
    )


def as_plan(obj, graph, **kw) -> "CachePlan":
    """Normalize a CachePlan / admission name / admission instance; None
    passes through (cache disabled)."""
    if obj is None or isinstance(obj, CachePlan):
        return obj
    return build_plan(graph, obj, **kw)


# ---------------------------------------------------------------------------
# numpy mirror of the device hit/miss counters
# ---------------------------------------------------------------------------
def cache_stats_np(pos: np.ndarray, ids: np.ndarray,
                   num_nodes: int) -> Tuple[int, int]:
    """(hits, misses) over the VALID entries of `ids` (sentinel
    `num_nodes` = padding) — the exact mirror of the device counters
    `repro.kernels.gather_cached.ops.cache_stats` returns."""
    ids = np.asarray(ids)
    valid = (ids >= 0) & (ids < num_nodes)
    hit = valid & (np.asarray(pos)[np.clip(ids, 0, num_nodes - 1)] >= 0)
    return int(hit.sum()), int((valid & ~hit).sum())


def cache_ref_updates_np(pos: np.ndarray, ids: np.ndarray,
                         capacity: int) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy mirror of the extended device counters
    `repro.kernels.gather_cached.ops.cache_ref_updates`: per-slot hit
    counts `(C,)` and per-node miss counts `(N,)` over the VALID entries
    of `ids` (same validity rule as `cache_stats_np`)."""
    pos = np.asarray(pos)
    ids = np.asarray(ids)
    num_nodes = len(pos)
    valid = (ids >= 0) & (ids < num_nodes)
    gid = np.clip(ids, 0, num_nodes - 1)
    sel = pos[gid]
    hit = valid & (sel >= 0)
    slot_hits = np.zeros(capacity, np.int32)
    np.add.at(slot_hits, sel[hit], 1)
    node_miss = np.zeros(num_nodes, np.int32)
    np.add.at(node_miss, gid[valid & ~hit], 1)
    return slot_hits, node_miss
