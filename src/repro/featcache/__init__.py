"""Device-resident feature-cache subsystem (paper §6.5 as a measurement).

    from repro import featcache

    plan = featcache.build_plan(graph, "presampled_freq", capacity=4096,
                                policy=policy, batch_size=512,
                                fanouts=(10, 10))
    out, hits, misses = featcache.gather_cached(
        plan.cache, feats, plan.pos, ids)

A `CachePlan` pins the hottest feature rows (chosen by a registered
admission policy — `degree_hot` / `community_freq` / `presampled_freq`)
into a compact `(C, F)` device array with an `int32[N]` position map;
`repro.kernels.gather_cached` serves every layer-0 feature read through it
(cache row on hit, global matrix on miss) and counts hits on device, so
the paper's cache-locality claim becomes a measured per-epoch hit rate
(`GNNTrainer(cache=...)`) instead of a simulation.

Admission comes in two flavors: STATIC (a frozen `CachePlan`) and DYNAMIC
(`featcache.dynamic`: `CachePlan.to_dynamic()` / `cache="dynamic"` — a
trainer-carried CLOCK second-chance state whose reference bits come from
the extended `gather_cached` counters and whose residency is re-admitted
at epoch boundaries by `dynamic.refill`, bit-matched to a numpy oracle).
The LRU/CLOCK simulators for fig9/fig10 live in `featcache.sim` (the old
`repro.core.cachesim` location is a deprecated shim); simulator and refill
share ONE tie-breaking rule, `featcache.sim.CLOCK_TIE_BREAK`.
"""
from repro.featcache.dynamic import DynamicCacheState, as_cache  # noqa: F401
from repro.featcache.plan import (AdmissionPolicy, CachePlan,   # noqa: F401
                                  CommunityFreqAdmission, DegreeHotAdmission,
                                  PresampledFreqAdmission, as_admission,
                                  as_plan, available_admissions, build_plan,
                                  cache_ref_updates_np, cache_stats_np,
                                  make_admission, register_admission,
                                  select_rows)
from repro.featcache.sim import (CLOCK_TIE_BREAK,               # noqa: F401
                                 clock_miss_rate, clock_replay,
                                 lru_miss_rate, policy_access_stream,
                                 static_miss_rate)
from repro.kernels.gather_cached.ops import (cache_ref_updates,  # noqa: F401
                                             cache_stats, gather_cached)

__all__ = [
    "AdmissionPolicy", "CachePlan", "CLOCK_TIE_BREAK",
    "CommunityFreqAdmission", "DegreeHotAdmission", "DynamicCacheState",
    "PresampledFreqAdmission", "as_admission", "as_cache", "as_plan",
    "available_admissions", "build_plan", "cache_ref_updates",
    "cache_ref_updates_np", "cache_stats", "cache_stats_np",
    "clock_miss_rate", "clock_replay", "gather_cached", "lru_miss_rate",
    "make_admission", "policy_access_stream", "register_admission",
    "select_rows", "static_miss_rate",
]
