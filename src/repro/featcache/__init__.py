"""Device-resident feature-cache subsystem (paper §6.5 as a measurement).

    from repro import featcache

    plan = featcache.build_plan(graph, "presampled_freq", capacity=4096,
                                policy=policy, batch_size=512,
                                fanouts=(10, 10))
    out, hits, misses = featcache.gather_cached(
        plan.cache, feats, plan.pos, ids)

A `CachePlan` pins the hottest feature rows (chosen by a registered
admission policy — `degree_hot` / `community_freq` / `presampled_freq`)
into a compact `(C, F)` device array with an `int32[N]` position map;
`repro.kernels.gather_cached` serves every layer-0 feature read through it
(cache row on hit, global matrix on miss) and counts hits on device, so
the paper's cache-locality claim becomes a measured per-epoch hit rate
(`GNNTrainer(cache=...)`) instead of a simulation. The LRU/CLOCK
simulators for fig9/fig10 live in `featcache.sim` (the old
`repro.core.cachesim` location is a deprecated shim).
"""
from repro.featcache.plan import (AdmissionPolicy, CachePlan,   # noqa: F401
                                  CommunityFreqAdmission, DegreeHotAdmission,
                                  PresampledFreqAdmission, as_admission,
                                  as_plan, available_admissions, build_plan,
                                  cache_stats_np, make_admission,
                                  register_admission, select_rows)
from repro.featcache.sim import (clock_miss_rate,               # noqa: F401
                                 lru_miss_rate, policy_access_stream,
                                 static_miss_rate)
from repro.kernels.gather_cached.ops import (cache_stats,       # noqa: F401
                                             gather_cached)

__all__ = [
    "AdmissionPolicy", "CachePlan", "CommunityFreqAdmission",
    "DegreeHotAdmission", "PresampledFreqAdmission", "as_admission",
    "as_plan", "available_admissions", "build_plan", "cache_stats",
    "cache_stats_np", "clock_miss_rate", "gather_cached", "lru_miss_rate",
    "make_admission", "policy_access_stream", "register_admission",
    "select_rows", "static_miss_rate",
]
