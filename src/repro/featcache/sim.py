"""Software-cache simulators (paper §6.5.1/§6.5.2 analogue).

The paper measures a DGL GPU-resident feature cache (UVA path) and MIG-cut
L2 capacities; neither exists on TPU, so fig9/fig10 *model* the dynamic
cache: replay the exact per-batch feature-access streams produced by each
policy through an LRU (or CLOCK) of a given capacity and report miss rates.
The paper's numbers to match qualitatively: baseline 35.46% vs
COMM-RAND-MIX-{50..0}% = 20.99/11.39/6.22/6.21% (Fig 9), and growing
speedups as capacity shrinks (Fig 10).

`lru_miss_rate` is a vectorized stack-distance implementation: an access is
an LRU hit iff its reuse distance (distinct ids accessed since the previous
access to the same id) is below the capacity, so the whole simulation
reduces to computing reuse distances — done here batch-at-a-time with
numpy (a sorted-positions rank query per batch plus a merge-counting pass
for intra-batch corrections) instead of the old per-access Python
`OrderedDict` loop, which survives as `_lru_miss_rate_ref` (the
loop-equivalence oracle).

The STATIC cache (`repro.featcache.plan.CachePlan`) is not simulated — the
trainer measures it (`gather_cached` hit counters); `static_miss_rate`
replays a host stream against a plan for the benchmarks' cross-check.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Iterable, Iterator, List

import numpy as np


# ---------------------------------------------------------------------------
# LRU: vectorized stack-distance simulation
# ---------------------------------------------------------------------------
def _count_prev_greater(p: np.ndarray) -> np.ndarray:
    """c[j] = #{i < j : p[i] > p[j]} — vectorized bottom-up merge counting
    (log(k) numpy passes). `p` must be int64 with values >= -1."""
    n = len(p)
    if n <= 1:
        return np.zeros(n, np.int64)
    m = 1 << (n - 1).bit_length()
    vals = np.full(m, -2, np.int64)             # -2: padding sentinel,
    vals[:n] = p                                # never counts as "greater"
    c = np.zeros(m, np.int64)
    srt = vals.copy()                           # progressively block-sorted
    off = int(vals.max()) + 3                   # per-row key offset (> all)
    s = 1
    while s < m:
        two = srt.reshape(-1, 2 * s)
        left = two[:, :s]                       # sorted ascending
        q = vals.reshape(-1, 2 * s)[:, s:]      # right half, original order
        rows = np.arange(two.shape[0])[:, None]
        lk = (rows * off + (left + 2)).ravel()  # globally sorted keys
        qk = (rows * off + (q + 2)).ravel()
        le = np.searchsorted(lk, qk, side="right") \
            - np.repeat(rows.ravel() * s, s)
        add = s - le                            # left elements > query
        tgt = (rows * 2 * s + s + np.arange(s)[None, :]).ravel()
        c[tgt] += add
        srt = np.sort(two, axis=1).ravel()
        s *= 2
    return c[:n]


def _distinct_chunks(arrays: List[np.ndarray]) -> Iterator[np.ndarray]:
    """Split the stream into maximal runs of DISTINCT ids (per-batch arrays
    are already deduped upstream, so this normally yields one chunk per
    batch; intra-batch duplicates just force extra cuts)."""
    for a in arrays:
        k = len(a)
        if k == 0:
            continue
        order = np.argsort(a, kind="stable")
        sa = a[order]
        prev = np.full(k, -1, np.int64)
        same = sa[1:] == sa[:-1]
        prev[order[1:][same]] = order[:-1][same]
        start = 0
        while start < k:
            dup = np.nonzero(prev[start:] >= start)[0]
            end = start + int(dup.min()) if len(dup) else k
            yield a[start:end]
            start = end


def lru_miss_rate(batches: Iterable[np.ndarray], capacity: int) -> float:
    """batches: per-batch arrays of accessed node ids (already deduped).

    Exactly equivalent to the `OrderedDict` LRU loop
    (`_lru_miss_rate_ref`): access t to id u hits iff the number of
    distinct OTHER ids accessed since u's previous access is < capacity.
    Per distinct-id chunk at stream offset t0, the reuse distance of entry
    j with previous position p_j is

        d_j = #{seen ids with last_pos > p_j}        (rank query, sorted)
            + (j - 1)                                (earlier in-chunk ids,
                                                      all repositioned > p_j)
            - #{i < j : p_i > p_j}                   (...minus the ones the
                                                      rank query counted at
                                                      their OLD position)
    """
    capacity = int(capacity)
    arrays = [np.asarray(b).ravel() for b in batches]
    total = int(sum(len(a) for a in arrays))
    if total == 0:
        return 1.0
    uniq, inv = np.unique(np.concatenate(arrays), return_inverse=True)
    splits = np.cumsum([len(a) for a in arrays])[:-1]
    inv_arrays = np.split(inv.astype(np.int64), splits)
    last_pos = np.full(len(uniq), -1, np.int64)
    hits = 0
    t0 = 0
    for u in _distinct_chunks(inv_arrays):
        k = len(u)
        p = last_pos[u]
        seen = np.sort(last_pos[last_pos >= 0])
        after = len(seen) - np.searchsorted(seen, p, side="right")
        d = after + np.arange(k) - _count_prev_greater(p)
        hits += int(((p >= 0) & (d < capacity)).sum())
        last_pos[u] = t0 + np.arange(k)
        t0 += k
    return 1.0 - hits / total


def _lru_miss_rate_ref(batches: Iterable[np.ndarray],
                       capacity: int) -> float:
    """The original per-access OrderedDict loop — kept as the
    loop-equivalence oracle for the vectorized `lru_miss_rate`."""
    cache: OrderedDict = OrderedDict()
    hits = 0
    total = 0
    for ids in batches:
        for u in np.asarray(ids):
            u = int(u)
            total += 1
            if u in cache:
                cache.move_to_end(u)
                hits += 1
            else:
                cache[u] = True
                if len(cache) > capacity:
                    cache.popitem(last=False)
    return 1.0 - hits / max(total, 1)


# ---------------------------------------------------------------------------
# CLOCK: second-chance approximation of LRU
# ---------------------------------------------------------------------------
CLOCK_TIE_BREAK = """THE CLOCK tie-breaking rule, shared verbatim by the
simulator (`clock_replay` / `clock_miss_rate`) and the on-device epoch
refill (`repro.featcache.dynamic.refill`) so the simulated and measured
caches are the same policy:

  1. victim among equal-priority slots (reference bit CLEAR — at the
     refill, clear AND strictly colder than the candidate): the FIRST
     such slot at or after the hand in cyclic slot order — the hand walk
     clears the bit of every slot it passes and stops at the first
     eligible one; the hand then advances one past the victim.
  2. empty slots fill in ascending slot order before any eviction.
  3. inserted rows start with the reference bit CLEAR; only reuse sets it.
  4. equal-priority CANDIDATES are considered in arrival order: stream
     order in the simulator; ascending node id at the refill (candidates
     there are sorted by miss frequency desc, node id asc — the same
     lexsort rule `plan.select_rows` uses).
  5. candidate vs incumbent at EQUAL frequency (refill only): the
     incumbent stays — admission requires strictly greater frequency."""


def clock_replay(batches: Iterable[np.ndarray], capacity: int):
    """CLOCK (second-chance) replacement: one reference bit per slot, a
    rotating hand that clears bits until it finds a victim. The cheap
    hardware-style stand-in for LRU, and the simulated target of the
    on-device admission loop (`repro.featcache.dynamic`). Tie-breaking
    follows `CLOCK_TIE_BREAK` exactly.

    Returns `(miss_rate, slot_id (C,), refbit (C,), hand, filled)` — the
    final cache state is exposed so tests can pin the tie rule."""
    capacity = int(capacity)
    slot_of = {}                                  # id -> slot
    slot_id = np.full(capacity, -1, np.int64)
    refbit = np.zeros(capacity, bool)
    hand = 0
    filled = 0
    hits = 0
    total = 0
    for ids in batches:
        for u in np.asarray(ids).ravel():
            u = int(u)
            total += 1
            s = slot_of.get(u)
            if s is not None:
                refbit[s] = True
                hits += 1
                continue
            if filled < capacity:
                s = filled                        # rule 2: fill in order
                filled += 1
            else:
                while refbit[hand]:               # rule 1: second chance
                    refbit[hand] = False
                    hand = (hand + 1) % capacity
                s = hand
                del slot_of[int(slot_id[s])]
                hand = (hand + 1) % capacity
            slot_id[s] = u
            slot_of[u] = s
            refbit[s] = False                     # rule 3: insert CLEAR
    return 1.0 - hits / max(total, 1), slot_id, refbit, hand, filled


def clock_miss_rate(batches: Iterable[np.ndarray], capacity: int) -> float:
    """Miss rate of `clock_replay`. NOTE: CLOCK is NOT a stack algorithm —
    unlike LRU it is neither pointwise dominated by LRU nor monotone in
    capacity (Belady-style anomalies exist; tests pin a counterexample).
    It tracks LRU from above on average, which is what fig9/fig10 report."""
    return clock_replay(batches, capacity)[0]


# ---------------------------------------------------------------------------
# static plan replay + access streams
# ---------------------------------------------------------------------------
def static_miss_rate(batches: Iterable[np.ndarray],
                     cached_ids: np.ndarray) -> float:
    """Host replay of a static cache (`CachePlan.cached_ids()`): the
    fraction of accesses NOT resident. Cross-checks the measured device
    counters (`gather_cached`) in the fig9/fig10 drivers."""
    cached = np.unique(np.asarray(cached_ids))
    hits = 0
    total = 0
    for ids in batches:
        a = np.asarray(ids).ravel()
        total += len(a)
        hits += int(np.isin(a, cached).sum())
    return 1.0 - hits / max(total, 1)


def policy_access_stream(graph, policy, batch_size, fanouts, n_batches=16,
                         seed=0) -> List[np.ndarray]:
    """Unique input-node ids per batch under `policy` (numpy builder),
    sampled through the policy's bound sampler. The shared `ctx` spans the
    whole stream, so LABOR's per-epoch ranks persist across batches — the
    cross-batch repetition is exactly what an LRU cache rewards."""
    from repro import sampling
    from repro.core import partition
    from repro.core.minibatch import build_batch_np
    rng = np.random.default_rng((seed, 0))  # salt 0: legacy stream slot
    batches = partition.batches_for_epoch(
        graph.train_ids, graph.communities, policy, batch_size, rng)
    sampler = sampling.for_policy(policy)
    ctx = {}
    out = []
    for b in batches[:n_batches]:
        _, level = build_batch_np(rng, graph, b, fanouts, sampler, ctx=ctx)
        out.append(level)
    return out
