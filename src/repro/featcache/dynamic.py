"""On-device CLOCK (second-chance) admission for the feature cache.

PR 4's `CachePlan` froze admission at plan time: a host-side policy picks
the rows once and the trainer only ever reads them. The paper's
cache-locality argument (Figs 9-10) is about the *actual* access
distribution a (policy, sampler) pair produces, which drifts from any
presample — so this module promotes the simulated CLOCK policy
(`featcache.sim.clock_miss_rate`) into trainer-carried mutable state: the
cache observes its own hits and misses on device and re-admits at epoch
boundaries.

State machine of one cache slot across an epoch:

      resident row hit                       epoch-boundary refill
    ┌──────────────────┐                  ┌────────────────────────────┐
    │ reference bit←1  │   hand passes:   │ bit clear & colder than a  │
    │ slot_freq += 1   │   bit 1 → 0,     │ candidate → EVICT; row is  │
    └──────────────────┘   slot survives  │ swapped, bit starts CLEAR  │
      miss on node u       (2nd chance)   └────────────────────────────┘
    ┌──────────────────┐
    │ freq[u] += 1     │  → u becomes an admission candidate
    └──────────────────┘

Per TRAIN batch (inside the jitted step, no host sync): `ref_updates`
turns the extended `gather_cached` counters
(`kernels.gather_cached.ops.cache_ref_updates`) into new reference bits,
per-slot hit counts, and the per-node candidate-frequency accumulator;
the trainer reassembles the state host-side (`with_refs`) so the
unchanged `(C, F)` cache array is never copied. Evaluation reads through
the cache but never feeds the counters — only the training distribution
drives admission.

At each epoch boundary (outside all differentiated code — refills are
VJP-invisible by construction) `refill` runs a FREQUENCY-GATED CLOCK
pass: candidates are the missed, non-resident nodes sorted by miss
frequency (desc, node id asc — `plan.select_rows`'s rule); for each, the
hand walks the ring clearing the reference bit of every slot it passes
and skipping slots that were referenced (the second chance) OR whose
occupant's epoch access count is at least the candidate's (the gate —
comparing a resident row's hits to a missed row's misses compares the
same thing: how often the epoch touched the row). The candidate claims
the first clear, strictly-colder slot; if a full scan (2C steps — one
rotation to strip bits, one to probe every slot clean) finds none, every
slot is at least as hot as this hottest remaining candidate, so the pass
ends exactly (colder candidates cannot do better). Cache rows are
exact copies of global feature rows, so a hit is bit-identical to the
uncached read and the trainer's loss trajectory is unchanged by where the
rows live. Tie-breaking is `featcache.sim.CLOCK_TIE_BREAK` — ONE rule
shared with the simulator.

`refill` is a jitted device path; `refill_np` is the pure-numpy oracle it
must match slot-for-slot (including the final hand position and the
reference bits a failed pass leaves cleared) — pinned by
tests/test_featcache_dynamic.py in Pallas interpret mode in CI.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace
from repro.featcache.plan import (CachePlan, as_plan, build_plan,
                                  cache_ref_updates_np)
from repro.kernels.gather_cached.ops import cache_ref_updates
from repro.resilience import faults


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["cache", "pos", "slot_ids", "refbit", "slot_freq", "freq",
                 "hand"],
    meta_fields=["capacity", "policy"])
@dataclass
class DynamicCacheState:
    """Trainer-carried CLOCK cache state (a pytree; rides through jit and
    into checkpoints for bit-exact resume).

    cache:     (C, F) float32 — exact copies of the resident feature rows.
    pos:       (N,) int32 — cache slot of node i, or -1 (not resident).
    slot_ids:  (C,) int32 — node id resident in each slot (-1 = empty).
    refbit:    (C,) int32 0/1 — CLOCK reference bits; set by hits, cleared
               only by the hand (they persist across epochs).
    slot_freq: (C,) int32 — per-slot hit counts THIS epoch (refill gate).
    freq:      (N,) int32 — per-node miss counts THIS epoch (candidates).
    hand:      () int32 — the clock hand.
    capacity / policy: static metadata (jit-hashable); `policy` names the
               admission that seeded the initial residency."""
    cache: jnp.ndarray
    pos: jnp.ndarray
    slot_ids: jnp.ndarray
    refbit: jnp.ndarray
    slot_freq: jnp.ndarray
    freq: jnp.ndarray
    hand: jnp.ndarray
    capacity: int
    policy: str

    def cached_ids(self) -> np.ndarray:
        """(<=C,) resident node ids in cache-row order (skips empty slots)."""
        ids = np.asarray(self.slot_ids)
        return ids[ids >= 0]

    def describe(self) -> str:
        return f"clock[{self.policy}]@C={self.capacity}"


def from_plan(plan: CachePlan) -> DynamicCacheState:
    """Seed the CLOCK state from a static plan: same residency, all
    reference bits clear, hand at slot 0, zeroed accumulators."""
    pos = np.asarray(plan.pos)
    C = int(plan.capacity)
    slot_ids = np.full(C, -1, np.int32)
    ids = np.where(pos >= 0)[0]
    slot_ids[pos[ids]] = ids
    return DynamicCacheState(
        cache=plan.cache,
        pos=plan.pos,
        slot_ids=jnp.asarray(slot_ids),
        refbit=jnp.zeros((C,), jnp.int32),
        slot_freq=jnp.zeros((C,), jnp.int32),
        freq=jnp.zeros((pos.shape[0],), jnp.int32),
        hand=jnp.zeros((), jnp.int32),
        capacity=C,
        policy=plan.policy,
    )


def as_cache(obj, graph, **kw):
    """Normalize ANY cache spec the trainer/stream accept: None passes
    through; `CachePlan` / `DynamicCacheState` instances pass through;
    an admission name builds a static plan; `"dynamic"` (or
    `"dynamic:<admission>"`, default admission `presampled_freq`) builds
    that static plan and promotes it to a CLOCK state."""
    if obj is None or isinstance(obj, (CachePlan, DynamicCacheState)):
        return obj
    if isinstance(obj, str) and (obj == "dynamic"
                                 or obj.startswith("dynamic:")):
        adm = obj.split(":", 1)[1] if ":" in obj else "presampled_freq"
        return from_plan(build_plan(graph, adm, **kw))
    return as_plan(obj, graph, **kw)


# ---------------------------------------------------------------------------
# per-batch reference-bit / frequency accumulation (inside jitted steps)
# ---------------------------------------------------------------------------
def ref_updates(state: DynamicCacheState, ids) -> Tuple:
    """Device path, called INSIDE the trainer's jitted step: fold one
    batch of reads into `(refbit, slot_freq, freq)`. Returns only the
    three updated arrays (not a new state) so the step's outputs never
    include — and jit never copies — the unchanged (C, F) cache array;
    `with_refs` reassembles host-side. Mirror: `ref_updates_np`."""
    slot_hits, node_miss = cache_ref_updates(state.pos, ids, state.capacity)
    return (jnp.maximum(state.refbit, (slot_hits > 0).astype(jnp.int32)),
            state.slot_freq + slot_hits,
            state.freq + node_miss)


def with_refs(state: DynamicCacheState, refs) -> DynamicCacheState:
    """Host-side reassembly of `ref_updates` output into a new state."""
    refbit, slot_freq, freq = refs
    return replace(state, refbit=refbit, slot_freq=slot_freq, freq=freq)


def ref_updates_np(state: Dict[str, np.ndarray], ids) -> Dict[str, np.ndarray]:
    """Numpy mirror of `ref_updates` over a `state_to_np` dict."""
    slot_hits, node_miss = cache_ref_updates_np(
        state["pos"], ids, len(state["slot_ids"]))
    out = dict(state)
    out["refbit"] = np.maximum(state["refbit"],
                               (slot_hits > 0).astype(np.int32))
    out["slot_freq"] = state["slot_freq"] + slot_hits
    out["freq"] = state["freq"] + node_miss
    return out


# ---------------------------------------------------------------------------
# epoch-boundary CLOCK eviction/refill
# ---------------------------------------------------------------------------
@jax.jit
def _refill_jit(state: DynamicCacheState, feats):
    C = state.capacity
    N = state.pos.shape[0]
    # candidates: missed NON-resident nodes, hottest first, ties -> lower
    # node id (the same lexsort rule as plan.select_rows)
    cand_freq = jnp.where(state.pos < 0, state.freq, 0).astype(jnp.int32)
    order = jnp.lexsort((jnp.arange(N), -cand_freq))
    cand_ids = order[:C].astype(jnp.int32)
    cand_fs = cand_freq[cand_ids]

    def step(carry, cand):
        cache, pos, slot_ids, refbit, slot_freq, hand, done, admitted = carry
        cid, f = cand
        active = jnp.logical_and(jnp.logical_not(done), f > 0)

        # frequency-gated second-chance walk: pass (and clear the bit of)
        # every slot that was referenced OR is at least as hot as the
        # candidate; stop at the first clear, strictly-colder slot. 2C
        # steps scan every slot clean — reaching it means no victim exists
        # for this (or, sorted desc, any later) candidate.
        def wcond(c):
            rb, h, s = c
            return jnp.logical_and(
                s < 2 * C,
                jnp.logical_or(rb[h] > 0, slot_freq[h] >= f))

        def wbody(c):
            rb, h, s = c
            return rb.at[h].set(0), (h + 1) % C, s + 1

        refbit, hand, steps = jax.lax.cond(
            active, lambda c: jax.lax.while_loop(wcond, wbody, c),
            lambda c: c, (refbit, hand, jnp.int32(0)))
        v = hand
        # equal frequency -> incumbent stays (see CLOCK_TIE_BREAK rule 5)
        admit = jnp.logical_and(active, steps < 2 * C)
        done = jnp.logical_or(done, jnp.logical_and(active,
                                                    jnp.logical_not(admit)))
        old = slot_ids[v]
        pos = pos.at[jnp.where(jnp.logical_and(admit, old >= 0),
                               old, N)].set(-1, mode="drop")
        pos = pos.at[jnp.where(admit, cid, N)].set(v, mode="drop")
        drop_v = jnp.where(admit, v, C)
        slot_ids = slot_ids.at[drop_v].set(cid, mode="drop")
        slot_freq = slot_freq.at[drop_v].set(f, mode="drop")
        refbit = refbit.at[drop_v].set(0, mode="drop")  # insert CLEAR
        cache = cache.at[drop_v].set(feats[cid].astype(cache.dtype),
                                     mode="drop")
        hand = jnp.where(admit, (v + 1) % C, hand)
        return (cache, pos, slot_ids, refbit, slot_freq, hand, done,
                admitted + admit.astype(jnp.int32)), None

    init = (state.cache, state.pos, state.slot_ids, state.refbit,
            state.slot_freq, state.hand.astype(jnp.int32),
            jnp.asarray(False), jnp.int32(0))
    (cache, pos, slot_ids, refbit, slot_freq, hand, _, admitted), _ = \
        jax.lax.scan(step, init, (cand_ids, cand_fs))
    new_state = replace(
        state, cache=cache, pos=pos, slot_ids=slot_ids, refbit=refbit,
        slot_freq=jnp.zeros_like(slot_freq),   # next epoch's counters
        freq=jnp.zeros_like(state.freq),
        hand=hand)
    return new_state, admitted


def refill(state: DynamicCacheState,
           feats) -> Tuple[DynamicCacheState, jnp.ndarray]:
    """Epoch-boundary frequency-gated CLOCK eviction/refill (jitted
    device path).

    Swaps cold slots for hot missed rows: candidates in (miss-frequency
    desc, node id asc) order each claim the first hand-walked slot that
    is clear AND strictly colder; a victimless full scan ends the pass
    (exact, not heuristic — see module docstring). Rows are copied
    from `feats` — the SAME (N, F) matrix the uncached path reads — so
    residency changes never perturb the loss. Epoch accumulators
    (`slot_freq`, `freq`) reset; reference bits persist (only the hand
    clears them). Returns `(new_state, admitted)` where `admitted` is the
    refill churn (an int32 scalar on device).

    Must be called OUTSIDE differentiated code (the trainer refills
    between batches at epoch boundaries). Oracle: `refill_np`."""
    with obs_trace.span("clock_refill", cat="cache"):
        new_state, admitted = _refill_jit(state, feats)
    spec = faults.fire("cache_corrupt")
    if spec is not None:
        # chaos site (repro.resilience): hand back a state whose
        # residency invariants are violated — the trainer's
        # `integrity_ok` check at this very boundary must catch it and
        # degrade to the uncached gather BEFORE any read goes through
        # the bad position map
        new_state = _corrupt_state(new_state,
                                   faults.active().payload_rng(spec))
    return new_state, admitted


def _corrupt_state(state: DynamicCacheState,
                   rng: np.random.Generator) -> DynamicCacheState:
    """Deterministic residency scramble (the `cache_corrupt` payload):
    point one extra node at an already-claimed slot, so the pos->slot
    map stops being a bijection and `integrity_ok` must fail."""
    pos = np.asarray(state.pos).copy()
    res = np.where(pos >= 0)[0]
    non = np.where(pos < 0)[0]
    if len(res) and len(non):
        pos[non[int(rng.integers(len(non)))]] = \
            pos[res[int(rng.integers(len(res)))]]
    elif len(res) >= 2:                 # full residency: cross two entries
        a, b = res[rng.permutation(len(res))[:2]]
        pos[a] = pos[b]
    else:
        return state                    # nothing corruptible (C ~ 0)
    return replace(state, pos=jnp.asarray(pos))


@jax.jit
def _integrity_jit(state: DynamicCacheState):
    C = state.capacity
    slots = jnp.arange(C, dtype=jnp.int32)
    resident = state.slot_ids >= 0
    # every resident slot's occupant must map straight back to it ...
    occ = jnp.clip(state.slot_ids, 0, state.pos.shape[0] - 1)
    ok = jnp.all(jnp.where(resident, state.pos[occ] == slots, True))
    # ... and be the ONLY claimant: resident pos entries == resident slots
    ok &= jnp.sum(state.pos >= 0) == jnp.sum(resident)
    ok &= jnp.all((state.pos >= -1) & (state.pos < C))
    ok &= jnp.all((state.refbit == 0) | (state.refbit == 1))
    return ok


def integrity_ok(state: DynamicCacheState) -> bool:
    """Cheap residency-invariant check (one jitted O(N + C) pass, one
    bool sync): the slot_ids<->pos maps must be a bijection over the
    resident rows, pos values in range, reference bits boolean. The
    trainer runs this at every epoch-boundary refill — the one point
    residency changes — and degrades to the uncached gather on failure
    (cache rows are bit-copies, so dropping the cache never perturbs the
    loss trajectory)."""
    return bool(_integrity_jit(state))


def refill_np(state: Dict[str, np.ndarray],
              feats: np.ndarray) -> Tuple[Dict[str, np.ndarray], int]:
    """Pure-numpy CLOCK refill — THE oracle `refill` must match
    slot-for-slot: residency, cache rows, reference bits (including the
    ones a failed pass leaves cleared), accumulator resets, and the final
    hand position. Operates on a `state_to_np` dict; returns
    `(new_state_dict, admitted)`."""
    cache = state["cache"].copy()
    pos = state["pos"].copy()
    slot_ids = state["slot_ids"].copy()
    refbit = state["refbit"].copy()
    slot_freq = state["slot_freq"].copy()
    freq = state["freq"]
    hand = int(state["hand"])
    C = len(slot_ids)
    cand_freq = np.where(pos < 0, freq, 0)
    order = np.lexsort((np.arange(len(freq)), -cand_freq))[:C]
    admitted = 0
    feats = np.asarray(feats)
    for cand in order:
        f = int(cand_freq[cand])
        if f <= 0:
            break                       # sorted desc: no candidates left
        steps = 0                       # frequency-gated second-chance walk
        while steps < 2 * C and (refbit[hand] > 0
                                 or int(slot_freq[hand]) >= f):
            refbit[hand] = 0
            hand = (hand + 1) % C
            steps += 1
        if steps >= 2 * C:
            break                       # every slot at least as hot: every
            # later (colder) candidate fails too
        v = hand
        old = int(slot_ids[v])
        if old >= 0:
            pos[old] = -1
        slot_ids[v] = cand
        pos[cand] = v
        cache[v] = feats[cand].astype(cache.dtype)
        slot_freq[v] = f
        refbit[v] = 0                   # insert CLEAR
        hand = (v + 1) % C
        admitted += 1
    out = dict(state)
    out.update(cache=cache, pos=pos, slot_ids=slot_ids, refbit=refbit,
               slot_freq=np.zeros_like(slot_freq),
               freq=np.zeros_like(freq),
               hand=np.asarray(hand, np.int32))
    return out, admitted


def state_to_np(state: DynamicCacheState) -> Dict[str, np.ndarray]:
    """Materialize the device state as a dict of numpy arrays (the mirror
    functions' representation; also handy for test equality checks)."""
    return {"cache": np.asarray(state.cache),
            "pos": np.asarray(state.pos),
            "slot_ids": np.asarray(state.slot_ids),
            "refbit": np.asarray(state.refbit),
            "slot_freq": np.asarray(state.slot_freq),
            "freq": np.asarray(state.freq),
            "hand": np.asarray(state.hand)}
