"""GNN model zoo on static-shape mini-batch towers: GraphSAGE (paper's
primary), GCN and GAT (paper §6.4).

All layers consume a `Block` (dense (n_dst, fanout) source-position gather +
self position), so aggregation is a masked mean/attention over the fanout
axis — the shape the `gather_mean` Pallas kernel targets.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.core.minibatch import Block, MiniBatch
from repro.models.lm.common import dense_init

Params = Dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_gnn(cfg: GNNConfig, key) -> Params:
    dims = [cfg.in_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1) \
        + [cfg.num_classes]
    layers = []
    for i in range(cfg.num_layers):
        k = jax.random.fold_in(key, i)
        ks = jax.random.split(k, 6)
        din, dout = dims[i], dims[i + 1]
        if cfg.model == "sage":
            layers.append({
                "w_self": dense_init(ks[0], (din, dout)),
                "w_neigh": dense_init(ks[1], (din, dout)),
                "b": jnp.zeros((dout,)),
            })
        elif cfg.model == "gcn":
            layers.append({
                "w": dense_init(ks[0], (din, dout)),
                "b": jnp.zeros((dout,)),
            })
        elif cfg.model == "gat":
            H = cfg.gat_heads
            dh = max(dout // H, 1)
            layers.append({
                "w": dense_init(ks[0], (din, H * dh)),
                "a_src": dense_init(ks[1], (H, dh)) * 0.1,
                "a_dst": dense_init(ks[2], (H, dh)) * 0.1,
                "b": jnp.zeros((H * dh,)),
                "w_out": dense_init(ks[3], (H * dh, dout))
                if H * dh != dout else None,
            })
        else:
            raise ValueError(cfg.model)
    return {"layers": layers}


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------
def _masked_mean(x_src, block: Block):
    """x_src: (n_src, D) -> (n_dst, D) mean over sampled neighbor slots."""
    g = x_src[block.src_pos]                          # (n_dst, r, D)
    m = block.edge_mask[..., None].astype(x_src.dtype)
    s = (g * m).sum(axis=1)
    cnt = jnp.maximum(m.sum(axis=1), 1.0)
    return s / cnt


def sage_layer(p, x_src, block: Block):
    h_self = x_src[block.self_pos]
    h_nbr = _masked_mean(x_src, block)
    return h_self @ p["w_self"] + h_nbr @ p["w_neigh"] + p["b"]


def gcn_layer(p, x_src, block: Block, deg_src, deg_dst):
    """Symmetric-normalized aggregation with self loops (global degrees)."""
    g = x_src[block.src_pos]                          # (n_dst, r, D)
    m = block.edge_mask[..., None].astype(x_src.dtype)
    cnt = jnp.maximum(block.edge_mask.sum(axis=1, keepdims=True), 1)
    # sampled-edge weight: deg_dst/r compensates fanout subsampling
    c_src = jax.lax.rsqrt(deg_src[block.src_pos].astype(jnp.float32) + 1.0)
    c_dst = jax.lax.rsqrt(deg_dst.astype(jnp.float32) + 1.0)
    w = (c_src * (deg_dst[:, None] / cnt)
         )[..., None].astype(x_src.dtype)
    agg = (g * m * w).sum(axis=1)
    h_self = x_src[block.self_pos] * (c_dst * c_dst)[:, None].astype(
        x_src.dtype)
    return (agg * c_dst[:, None].astype(x_src.dtype) + h_self) @ p["w"] \
        + p["b"]


def gat_layer(p, x_src, block: Block):
    H, dh = p["a_src"].shape
    z = x_src @ p["w"]                                # (n_src, H*dh)
    z = z.reshape(z.shape[0], H, dh)
    z_nbr = z[block.src_pos]                          # (n_dst, r, H, dh)
    z_self = z[block.self_pos]                        # (n_dst, H, dh)
    e_src = jnp.einsum("nrhd,hd->nrh", z_nbr, p["a_src"])
    e_dst = jnp.einsum("nhd,hd->nh", z_self, p["a_dst"])
    e_self = jnp.einsum("nhd,hd->nh", z_self, p["a_src"]) + e_dst
    e = jax.nn.leaky_relu(e_src + e_dst[:, None], 0.2)  # (n_dst, r, H)
    e = jnp.where(block.edge_mask[..., None], e, -1e30)
    e_all = jnp.concatenate(
        [e, jax.nn.leaky_relu(e_self)[:, None]], axis=1)  # + self edge
    alpha = jax.nn.softmax(e_all, axis=1)
    vals = jnp.concatenate([z_nbr, z_self[:, None]], axis=1)
    out = jnp.einsum("nrh,nrhd->nhd", alpha, vals).reshape(
        z_self.shape[0], H * dh) + p["b"]
    if p.get("w_out") is not None:
        out = out @ p["w_out"]
    return out


# ---------------------------------------------------------------------------
# full model over a batch tower
# ---------------------------------------------------------------------------
def apply_gnn(cfg: GNNConfig, params: Params, batch: MiniBatch, x,
              degrees=None, *, train: bool = False, dropout_key=None):
    """x: (cap_L, in_dim) gathered input features (masked). Returns logits
    aligned with batch.roots order."""
    x = x * batch.node_mask[:, None].astype(x.dtype)
    L = len(batch.blocks)
    for i, block in enumerate(batch.blocks):
        p = params["layers"][i]
        if cfg.model == "sage":
            x = sage_layer(p, x, block)
        elif cfg.model == "gcn":
            # per-level degrees gathered from the global degree array;
            # blocks[i] maps level (L-i) -> (L-i-1)
            n = degrees.shape[0]
            d_src = degrees[jnp.minimum(batch.levels[L - i], n - 1)]
            d_dst = degrees[jnp.minimum(batch.levels[L - i - 1], n - 1)]
            x = gcn_layer(p, x, block, d_src, d_dst)
        else:
            x = gat_layer(p, x, block)
        x = x * block.dst_mask[:, None].astype(x.dtype)
        if i < len(batch.blocks) - 1:
            x = jax.nn.relu(x)
            if train and cfg.dropout > 0 and dropout_key is not None:
                keep = 1.0 - cfg.dropout
                mask = jax.random.bernoulli(
                    jax.random.fold_in(dropout_key, i), keep, x.shape)
                x = jnp.where(mask, x / keep, 0.0)
    return x
