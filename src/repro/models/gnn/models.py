"""GNN model zoo on static-shape mini-batch towers: GraphSAGE (paper's
primary), GCN and GAT (paper §6.4).

All layers consume a `Block` (dense (n_dst, fanout) source-position gather +
self position), so aggregation is a per-edge-weighted reduce over the fanout
axis — exactly the shape of the fused `repro.kernels.gather_agg` Pallas
kernel. Every layer expresses its aggregation as scalar per-edge weights
(SAGE: mask/count, GCN: folded degree normalizers, GAT: attention alphas)
over one shared `gather_agg` call, so the (n_dst, fanout, F) gathered
intermediate never materializes in HBM on the kernel path — forward or
backward. `GNNConfig.agg_impl` selects the backend (see
`repro.kernels.gather_agg.ops.resolve_agg_impl`).

`apply_gnn(..., feats_global=True)` additionally composes layer-0 source
positions with `batch.node_ids`, gathering input features straight from the
global (N, F) feature matrix — the per-batch HBM feature traffic is then
exactly the paper's Fig-6 working-set metric, with no up-front (cap_L, F)
copy.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.core.minibatch import MiniBatch
from repro.kernels.gather_agg.ops import gather_agg, resolve_agg_impl
from repro.kernels.gather_cached.ops import gather_cached
from repro.models.lm.common import dense_init

Params = Dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_gnn(cfg: GNNConfig, key) -> Params:
    dims = [cfg.in_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1) \
        + [cfg.num_classes]
    layers = []
    for i in range(cfg.num_layers):
        k = jax.random.fold_in(key, i)
        ks = jax.random.split(k, 6)
        din, dout = dims[i], dims[i + 1]
        if cfg.model == "sage":
            layers.append({
                "w_self": dense_init(ks[0], (din, dout)),
                "w_neigh": dense_init(ks[1], (din, dout)),
                "b": jnp.zeros((dout,)),
            })
        elif cfg.model == "gcn":
            layers.append({
                "w": dense_init(ks[0], (din, dout)),
                "b": jnp.zeros((dout,)),
            })
        elif cfg.model == "gat":
            H = cfg.gat_heads
            dh = max(dout // H, 1)
            layers.append({
                "w": dense_init(ks[0], (din, H * dh)),
                "a_src": dense_init(ks[1], (H, dh)) * 0.1,
                "a_dst": dense_init(ks[2], (H, dh)) * 0.1,
                "b": jnp.zeros((H * dh,)),
                "w_out": dense_init(ks[3], (H * dh, dout))
                if H * dh != dout else None,
            })
        else:
            raise ValueError(cfg.model)
    return {"layers": layers}


# ---------------------------------------------------------------------------
# layers — each one reduces to gather_agg(x_tab, src_idx, per-edge weights).
# `x_tab` is the source feature table: the previous level's activations, or
# the GLOBAL feature matrix at layer 0 under feats_global (src_idx then
# holds composed global row ids).
# ---------------------------------------------------------------------------
def _masked_mean(x_tab, src_idx, edge_mask, impl: str = "jnp"):
    """(n_dst, r)-indexed mean over valid neighbor slots -> (n_dst, F)."""
    m = edge_mask.astype(jnp.float32)
    w = m / jnp.maximum(m.sum(axis=1, keepdims=True), 1.0)
    return gather_agg(x_tab, src_idx, w, impl=impl).astype(x_tab.dtype)


def sage_layer(p, x_tab, src_idx, self_idx, edge_mask, *, impl="jnp"):
    h_self = x_tab[self_idx]
    h_nbr = _masked_mean(x_tab, src_idx, edge_mask, impl)
    return h_self @ p["w_self"] + h_nbr @ p["w_neigh"] + p["b"]


def gcn_layer(p, x_tab, src_idx, self_idx, edge_mask, deg_src_edge, deg_dst,
              *, impl="jnp"):
    """Symmetric-normalized aggregation with self loops (global degrees).

    All normalizers fold into the per-edge weight: mask * rsqrt(deg_src+1)
    * (deg_dst / sampled_count)  * rsqrt(deg_dst+1) — deg_dst/count
    compensates fanout subsampling."""
    m = edge_mask.astype(jnp.float32)
    cnt = jnp.maximum(edge_mask.sum(axis=1, keepdims=True), 1)
    c_src = jax.lax.rsqrt(deg_src_edge.astype(jnp.float32) + 1.0)
    c_dst = jax.lax.rsqrt(deg_dst.astype(jnp.float32) + 1.0)
    w = m * c_src * (deg_dst[:, None] / cnt) * c_dst[:, None]
    agg = gather_agg(x_tab, src_idx, w, impl=impl).astype(x_tab.dtype)
    h_self = x_tab[self_idx] * (c_dst * c_dst)[:, None].astype(x_tab.dtype)
    return (agg + h_self) @ p["w"] + p["b"]


def gat_layer(p, x_tab, src_idx, self_idx, edge_mask, *, impl="jnp"):
    H, dh = p["a_src"].shape
    n_dst, r = src_idx.shape
    z = (x_tab @ p["w"]).reshape(-1, H, dh)           # (n_src, H, dh)
    # per-SOURCE attention logits: scores are linear in z, so gather the
    # (n_src, H) scalars instead of (n_dst, r, H, dh) projected rows
    s_src = jnp.einsum("nhd,hd->nh", z, p["a_src"])
    z_self = z[self_idx]                              # (n_dst, H, dh)
    e_src = s_src[src_idx]                            # (n_dst, r, H)
    e_dst = jnp.einsum("nhd,hd->nh", z_self, p["a_dst"])
    e_self = jnp.einsum("nhd,hd->nh", z_self, p["a_src"]) + e_dst
    e = jax.nn.leaky_relu(e_src + e_dst[:, None], 0.2)  # (n_dst, r, H)
    e = jnp.where(edge_mask[..., None], e, -1e30)
    e_all = jnp.concatenate(
        [e, jax.nn.leaky_relu(e_self, 0.2)[:, None]], axis=1)  # + self edge
    alpha = jax.nn.softmax(e_all, axis=1)             # (n_dst, r+1, H)
    a_nbr, a_self = alpha[:, :r], alpha[:, r]
    if impl == "pallas":
        # fold heads into the row axis: row (s*H + h) of zf is head h of
        # source s, so one gather_agg call reduces all heads, with alpha
        # flowing through the kernel's dw path for attention gradients
        zf = z.reshape(-1, dh)
        idx2 = (src_idx[:, None, :] * H +
                jnp.arange(H, dtype=src_idx.dtype)[None, :, None])
        w2 = a_nbr.transpose(0, 2, 1)                 # (n_dst, H, r)
        out = gather_agg(zf, idx2.reshape(n_dst * H, r),
                         w2.reshape(n_dst * H, r), impl=impl)
        out = out.reshape(n_dst, H, dh)
    else:
        out = jnp.einsum("nrh,nrhd->nhd", a_nbr, z[src_idx])
    out = out + a_self[..., None] * z_self
    out = out.reshape(n_dst, H * dh) + p["b"]
    if p.get("w_out") is not None:
        out = out @ p["w_out"]
    return out


# ---------------------------------------------------------------------------
# full model over a batch tower
# ---------------------------------------------------------------------------
def apply_gnn(cfg: GNNConfig, params: Params, batch: MiniBatch, x,
              degrees=None, *, train: bool = False, dropout_key=None,
              feats_global: bool = False, cache=None):
    """Returns logits aligned with batch.roots order.

    x: the input features. With feats_global=False (legacy), x is the
    pre-gathered (cap_L, in_dim) input-level table (callers do
    `feats[batch.node_ids]` — e.g. the sharded halo-gather path). With
    feats_global=True, x is the FULL (N, in_dim) feature matrix and layer 0
    gathers rows directly through composed `node_ids[src_pos]` indices — no
    (cap_L, in_dim) copy is ever made, so per-batch feature HBM reads equal
    the Fig-6 working-set bytes.

    cache: an optional `repro.featcache.CachePlan` or dynamic CLOCK
    `DynamicCacheState` — anything with `.cache` (C, F) rows and `.pos`
    (N,) map (requires feats_global=True). Layer-0 feature reads then
    route through the two-level `gather_cached` kernel: the
    (cap_L, in_dim) input level is assembled once per batch, each row
    served from the device-resident cache on hit and from the global
    matrix on miss. Cache rows are exact copies, so outputs are
    bit-identical to the uncached path regardless of residency; the
    trainer measures hit rates (and feeds dynamic admission) separately
    on the same position map. The gather backend follows `cfg.agg_impl`.
    """
    impl = resolve_agg_impl(cfg.agg_impl)
    L = len(batch.blocks)
    if cache is not None:
        if not feats_global:
            raise ValueError("cache= requires feats_global=True "
                             "(x must be the full (N, F) feature matrix)")
        x, _, _ = gather_cached(cache.cache, x, cache.pos, batch.node_ids,
                                impl=cfg.agg_impl)
        x = x * batch.node_mask[:, None].astype(x.dtype)
        feats_global = False
    elif not feats_global:
        x = x * batch.node_mask[:, None].astype(x.dtype)
    elif cfg.model == "gat":
        # GAT projects every unique source row BEFORE gathering (projecting
        # per edge would multiply the matmul FLOPs by the fanout), so the
        # input level is materialized once here; the per-edge (r, H*dh)
        # intermediates are still never built on the kernel path.
        x = x[jnp.minimum(batch.node_ids, x.shape[0] - 1)] \
            * batch.node_mask[:, None].astype(x.dtype)
        feats_global = False
    for i, block in enumerate(batch.blocks):
        p = params["layers"][i]
        if i == 0 and feats_global:
            gid = jnp.minimum(batch.node_ids, x.shape[0] - 1)
            src_idx = gid[block.src_pos]
            self_idx = gid[block.self_pos]
        else:
            src_idx, self_idx = block.src_pos, block.self_pos
        if cfg.model == "sage":
            x = sage_layer(p, x, src_idx, self_idx, block.edge_mask,
                           impl=impl)
        elif cfg.model == "gcn":
            # per-level degrees gathered from the global degree array;
            # blocks[i] maps level (L-i) -> (L-i-1)
            n = degrees.shape[0]
            d_src = degrees[jnp.minimum(batch.levels[L - i], n - 1)]
            deg_dst = degrees[jnp.minimum(batch.levels[L - i - 1], n - 1)]
            x = gcn_layer(p, x, src_idx, self_idx, block.edge_mask,
                          d_src[block.src_pos], deg_dst, impl=impl)
        else:
            x = gat_layer(p, x, src_idx, self_idx, block.edge_mask,
                          impl=impl)
        x = x * block.dst_mask[:, None].astype(x.dtype)
        if i < len(batch.blocks) - 1:
            x = jax.nn.relu(x)
            if train and cfg.dropout > 0 and dropout_key is not None:
                keep = 1.0 - cfg.dropout
                mask = jax.random.bernoulli(
                    jax.random.fold_in(dropout_key, i), keep, x.shape)
                x = jnp.where(mask, x / keep, 0.0)
    return x
