"""Subgraph / full-graph GNN execution (ClusterGCN batches + the full-batch
training baseline from paper §2).

Unlike the sampled tower (`apply_gnn`), these run L layers over ONE node set
with an explicit padded edge list, using segment-sum aggregation.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig


@jax.tree_util.register_dataclass
@dataclass
class SubgraphBatch:
    nodes: jnp.ndarray        # (cap_n,) node ids (sentinel-padded)
    node_mask: jnp.ndarray    # (cap_n,)
    edge_src: jnp.ndarray     # (cap_e,) positions into nodes
    edge_dst: jnp.ndarray     # (cap_e,)
    edge_mask: jnp.ndarray    # (cap_e,)
    labels: jnp.ndarray       # (cap_n,)
    loss_mask: jnp.ndarray    # (cap_n,) train-root indicator


def sage_subgraph_apply(cfg: GNNConfig, params, batch: SubgraphBatch, x,
                        *, train=False, dropout_key=None):
    """Mean-aggregator SAGE over an explicit edge list."""
    n = batch.nodes.shape[0]
    x = x * batch.node_mask[:, None].astype(x.dtype)
    for i, p in enumerate(params["layers"]):
        m = batch.edge_mask.astype(x.dtype)
        msg = x[batch.edge_src] * m[:, None]
        agg = jax.ops.segment_sum(msg, batch.edge_dst, num_segments=n)
        cnt = jax.ops.segment_sum(m, batch.edge_dst, num_segments=n)
        mean = agg / jnp.maximum(cnt, 1.0)[:, None]
        x = x @ p["w_self"] + mean @ p["w_neigh"] + p["b"]
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
            if train and cfg.dropout > 0 and dropout_key is not None:
                keep = 1.0 - cfg.dropout
                mk = jax.random.bernoulli(
                    jax.random.fold_in(dropout_key, i), keep, x.shape)
                x = jnp.where(mk, x / keep, 0.0)
        x = x * batch.node_mask[:, None].astype(x.dtype)
    return x
