"""Group-local sort-based Mixture-of-Experts (TPU-native dispatch).

Tokens are reshaped into G static dispatch groups (~4096 tokens each; one or
more groups per device). Each group independently routes, sorts and packs its
tokens into fixed-capacity expert slots — a *batched* gather/scatter over the
group axis, which the SPMD partitioner keeps fully local. The grouped expert
matmul (G, E, C, d) x (E, d, f) then contracts with experts sharded over
`model` (expert parallelism); the group-axis resharding on entry/exit is the
EP all-to-all. FLOPs scale with active params and every shape is static.

(The first implementation used one global argsort over all T*K assignments;
the partitioner materialized replicated (T*K, d) dispatch cotangents —
386 GiB/device on qwen3-moe train_4k. Group-local dispatch is the fix; see
EXPERIMENTS.md §Perf.)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models.lm.common import dense_init

GROUP_TOKENS = 4096          # target tokens per dispatch group


def moe_group_count(T: int) -> int:
    """Dispatch groups: a multiple of the mesh size (so the group axis
    shards over every device) with ~GROUP_TOKENS tokens per group."""
    ctx = shd.active()
    total = (ctx.fsdp * max(ctx.tp, 1)) if ctx is not None else 1
    if total > 1 and T % total == 0:
        return total * max(1, T // (GROUP_TOKENS * total))
    if T % GROUP_TOKENS == 0:
        return T // GROUP_TOKENS
    return 1


def moe_capacity(T_g: int, cfg) -> int:
    c = int(T_g * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, (c + 7) // 8 * 8)


def init_moe(key, cfg):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], (d, E)),
        "wg": dense_init(ks[1], (E, d, f), in_axis=-2),
        "wu": dense_init(ks[2], (E, d, f), in_axis=-2),
        "wd": dense_init(ks[3], (E, f, d), in_axis=-2),
    }
    if cfg.shared_d_ff:
        sf = cfg.shared_d_ff
        p.update({
            "swg": dense_init(ks[4], (d, sf)),
            "swu": dense_init(ks[5], (d, sf)),
            "swd": dense_init(ks[6], (sf, d)),
            "sgate": dense_init(ks[7], (d, 1)),
        })
    return p


def moe_ffn(x, p, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (T, d) token-major. Returns (out (T, d), aux_loss scalar)."""
    T, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    G = moe_group_count(T)
    Tg = T // G
    C = moe_capacity(Tg, cfg)
    dtype = x.dtype

    xr = shd.act_moe_grouped(x.reshape(G, Tg, d))               # (G,Tg,d)
    logits = xr.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (G,Tg,E)
    topv, topi = jax.lax.top_k(probs, K)                        # (G,Tg,K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing aux (Switch-style), computed per group ----
    counts = jax.vmap(
        lambda t: jnp.zeros((E,), jnp.float32).at[t.reshape(-1)].add(1.0)
    )(topi)
    frac_tokens = counts / Tg
    frac_probs = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, -1)) \
        * cfg.router_aux_coef

    # ---- group-local dispatch/combine, vmapped over groups so every
    # gather/scatter is an explicitly-batched row op the partitioner keeps
    # local to the group's device ----
    def route(topi_g):
        flat_e = topi_g.reshape(-1)                             # (Tg*K,)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E))
        slot = jnp.arange(Tg * K) - starts[sorted_e]
        keep = slot < C
        dest = jnp.where(keep, sorted_e * C + slot, E * C)
        return order, dest, keep

    def dispatch(x_g, topi_g):
        order, dest, keep = route(topi_g)
        token_id = order // K
        return jnp.zeros((E * C, d), dtype).at[dest].set(
            x_g[token_id], mode="drop")

    def combine(og_g, x_g, topi_g, topv_g):
        order, dest, keep = route(topi_g)
        token_id = order // K
        y_sorted = og_g[jnp.where(keep, dest, 0)] * \
            keep[:, None].astype(dtype)
        w_sorted = topv_g.reshape(-1)[order].astype(dtype)
        return jnp.zeros((Tg, d), dtype).at[token_id].add(
            y_sorted * w_sorted[:, None])

    xg = jax.vmap(dispatch)(xr, topi)                           # (G,E*C,d)
    xg = shd.act_moe_grouped(xg)           # keep the scatter group-local
    xg = shd.act_moe_dispatch(xg.reshape(G, E, C, d))           # EP a2a here

    # ---- grouped expert matmul (gated) ----
    wg, wu, wd = (p["wg"].astype(dtype), p["wu"].astype(dtype),
                  p["wd"].astype(dtype))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xg, wg))
    h = shd.act_moe_dispatch(h * jnp.einsum("gecd,edf->gecf", xg, wu))
    og = shd.act_moe_dispatch(jnp.einsum("gecf,efd->gecd", h, wd))
    og = shd.act_moe_grouped(og.reshape(G, E * C, d))           # a2a back

    # ---- combine: gather back + weighted scatter-add over top-k ----
    y = jax.vmap(combine)(og, xr, topi, topv)                   # (G,Tg,d)
    y = shd.act_moe_grouped(y).reshape(T, d)

    # ---- shared expert (qwen2-moe) ----
    if cfg.shared_d_ff:
        hs = jax.nn.silu(x @ p["swg"].astype(dtype)) * \
            (x @ p["swu"].astype(dtype))
        ys = hs @ p["swd"].astype(dtype)
        gate = jax.nn.sigmoid(
            (x @ p["sgate"].astype(dtype)).astype(jnp.float32))
        y = y + ys * gate.astype(dtype)
    return y, aux


def moe_ref(x, p, cfg):
    """Dense per-expert oracle (no capacity drops) for small-shape tests."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(x @ p["wg"][e]) * (x @ p["wu"][e])
        oe = h @ p["wd"][e]
        w = jnp.sum(jnp.where(topi == e, topv, 0.0), axis=-1)
        y = y + oe.astype(jnp.float32) * w[:, None]
    if cfg.shared_d_ff:
        hs = jax.nn.silu(x @ p["swg"]) * (x @ p["swu"])
        ys = hs @ p["swd"]
        gate = jax.nn.sigmoid(x @ p["sgate"])
        y = y + ys * gate
    return y.astype(x.dtype)
