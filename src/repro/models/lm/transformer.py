"""Generic LM-family model covering all 10 assigned architectures.

One functional model: ``init`` builds an fp32 param pytree with layer params
stacked on a leading L axis (scan-over-layers); ``apply`` runs train/prefill;
``decode_step`` runs one serving step against a cache pytree. Family dispatch
(dense / moe / rwkv / hybrid / enc-dec / vlm) happens inside the layer body.

Sharding is injected through `repro.dist.sharding` activation constraints,
which no-op outside a mesh context, so the same code runs CPU smoke tests and
the 512-chip dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import sharding as shd
from repro.models.lm import rwkv6, ssm
from repro.models.lm.attention import decode_attention, flash_attention
from repro.models.lm.common import (activation, apply_rope, dense_init,
                                    embed_init, norm_apply, norm_init,
                                    rmsnorm, sinusoidal_positions)
from repro.models.lm.moe import init_moe, moe_ffn

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_attn(key, cfg: ModelConfig, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, qd)),
        "wk": dense_init(ks[1], (d, kvd)),
        "wv": dense_init(ks[2], (d, kvd)),
        "wo": dense_init(ks[3], (qd, d)),
    }
    if cfg.qkv_bias and not cross:
        p.update({"bq": jnp.zeros((qd,)), "bk": jnp.zeros((kvd,)),
                  "bv": jnp.zeros((kvd,))})
    if cfg.qk_norm and not cross:
        p.update({"qnorm": jnp.zeros((cfg.head_dim,)),
                  "knorm": jnp.zeros((cfg.head_dim,))})
    return p


def _init_mlp(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_bias:     # whisper-style plain MLP
        return {"w1": dense_init(ks[0], (d, ff)), "b1": jnp.zeros((ff,)),
                "w2": dense_init(ks[1], (ff, d)), "b2": jnp.zeros((d,))}
    return {"wg": dense_init(ks[0], (d, ff)),
            "wu": dense_init(ks[1], (d, ff)),
            "wd": dense_init(ks[2], (ff, d))}


def _init_layer(key, cfg: ModelConfig, *, decoder: bool):
    ks = jax.random.split(key, 8)
    p: Params = {"norm1": norm_init(cfg, cfg.d_model),
                 "norm2": norm_init(cfg, cfg.d_model)}
    if cfg.rwkv:
        p["time"] = rwkv6.init_time_mix(ks[0], cfg)
        p["chan"] = rwkv6.init_channel_mix(ks[1], cfg)
        return p
    p["attn"] = _init_attn(ks[0], cfg)
    if cfg.hybrid:
        p["ssm"] = ssm.init_ssm(ks[1], cfg)
        p["norm_attn_out"] = {"scale": jnp.zeros((cfg.q_dim,))}
        p["norm_ssm_out"] = {"scale": jnp.zeros((cfg.d_model,))}
    if cfg.encoder_decoder and decoder:
        p["cross"] = _init_attn(ks[2], cfg, cross=True)
        p["norm_cross"] = norm_init(cfg, cfg.d_model)
    if cfg.moe:
        p["moe"] = init_moe(ks[3], cfg)
    else:
        p["mlp"] = _init_mlp(ks[4], cfg)
    return p


def init(cfg: ModelConfig, key, max_seq: int = 4096) -> Params:
    ks = jax.random.split(key, 8)
    V, d = cfg.padded_vocab, cfg.d_model

    def stack_layers(key, n, decoder):
        keys = jax.random.split(key, n)
        return jax.vmap(lambda k: _init_layer(k, cfg, decoder=decoder))(keys)

    params: Params = {
        "embed": embed_init(ks[0], (V, d)),
        "layers": stack_layers(ks[1], cfg.num_layers, True),
        "final_norm": norm_init(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[2], (d, V))
    if cfg.learned_pos:
        params["pos_embed"] = embed_init(ks[3], (max_seq, d))
    if cfg.encoder_decoder:
        params["encoder"] = {
            "enc_layers": stack_layers(ks[4], cfg.num_encoder_layers, False),
            "final_norm": norm_init(cfg, d),
        }
    return params


def param_count(params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(params)
               if hasattr(x, "size"))


def abstract_params(cfg: ModelConfig, max_seq: int = 4096):
    """Shape-only param tree (no allocation) for the dry-run."""
    return jax.eval_shape(
        lambda k: init(cfg, k, max_seq), jax.random.key(0))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _qkv(cfg, p, x, kv_src=None):
    """Project to (B,S,H,hd)/(B,S,KH,hd). kv_src: cross-attn source."""
    B, S, _ = x.shape
    dt = x.dtype
    src = x if kv_src is None else kv_src
    q = x @ p["wq"].astype(dt)
    k = src @ p["wk"].astype(dt)
    v = src @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, src.shape[1], cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, src.shape[1], cfg.num_kv_heads, cfg.head_dim)
    if "qnorm" in p:
        q = rmsnorm(q, p["qnorm"], cfg.norm_eps)
        k = rmsnorm(k, p["knorm"], cfg.norm_eps)
    return q, k, v


def _attn_train(cfg, p, x, positions, is_global, *, causal=True,
                kv_src=None, use_rope=True):
    """Returns (pre-wo output (B,S,q_dim), (k, v) as stored in a cache)."""
    q, k, v = _qkv(cfg, p, x, kv_src)
    if use_rope and not cfg.learned_pos and kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta,
                       cfg.mrope_sections if cfg.mrope else None)
        k = apply_rope(k, positions, cfg.rope_theta,
                       cfg.mrope_sections if cfg.mrope else None)
    q, k, v = shd.act_heads(q), shd.act_heads(k), shd.act_heads(v)
    out = flash_attention(q, k, v, causal=causal, window=cfg.window,
                          is_global=is_global)
    out = shd.act_heads(out)
    B, S = x.shape[:2]
    return out.reshape(B, S, cfg.q_dim), (k, v)


def _mlp(cfg, p, x):
    dt = x.dtype
    act = activation(cfg.act)
    if "w1" in p:
        h = act(x @ p["w1"].astype(dt) + p["b1"].astype(dt))
        return h @ p["w2"].astype(dt) + p["b2"].astype(dt)
    h = act(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
    return h @ p["wd"].astype(dt)


def _ffn(cfg, p, x):
    """Returns (out, aux)."""
    if cfg.moe:
        B, S, d = x.shape
        y, aux = moe_ffn(x.reshape(B * S, d), p["moe"], cfg)
        return y.reshape(B, S, d), aux
    return _mlp(cfg, p["mlp"], x), jnp.float32(0)


def _layer_train(cfg, p, x, positions, is_global, enc_out=None,
                 collect=False):
    """One decoder layer; returns (x, aux, cache_extras_or_None).

    Pre-residual outputs get `act_partial_out` constraints so the TP
    reductions lower as reduce-scatter into the sequence-parallel shard
    (all-reduce + slice otherwise; see EXPERIMENTS.md §Perf)."""
    extras = None
    if cfg.rwkv:
        y, st = rwkv6.time_mix(norm_apply(cfg, x, p["norm1"]), p["time"], cfg)
        x = shd.act_residual(x + shd.act_partial_out(y))
        y, sc = rwkv6.channel_mix(norm_apply(cfg, x, p["norm2"]), p["chan"],
                                  cfg)
        if collect:
            extras = {"s": st["s"], "shift_t": st["shift"], "shift_c": sc}
        return shd.act_residual(x + shd.act_partial_out(y)), \
            jnp.float32(0), extras

    h = norm_apply(cfg, x, p["norm1"])
    attn_out, (k, v) = _attn_train(cfg, p["attn"], h, positions, is_global)
    if collect:
        extras = {"k": k, "v": v}
    if cfg.hybrid:
        ssm_out, sst = ssm.ssm_block(h, p["ssm"], cfg)
        if collect:
            extras.update(h=sst["h"], conv=sst["conv"])
        attn_out = 0.5 * (rmsnorm(attn_out, p["norm_attn_out"]["scale"],
                                  cfg.norm_eps) @ p["attn"]["wo"].astype(x.dtype)
                          + rmsnorm(ssm_out, p["norm_ssm_out"]["scale"],
                                    cfg.norm_eps))
    else:
        attn_out = attn_out @ p["attn"]["wo"].astype(x.dtype)
    x = shd.act_residual(x + shd.act_partial_out(attn_out))

    if enc_out is not None:
        hc = norm_apply(cfg, x, p["norm_cross"])
        c, _ = _attn_train(cfg, p["cross"], hc, positions, True, causal=False,
                           kv_src=enc_out, use_rope=False)
        c = shd.act_partial_out(c @ p["cross"]["wo"].astype(x.dtype))
        x = shd.act_residual(x + c)

    h2 = norm_apply(cfg, x, p["norm2"])
    ff, aux = _ffn(cfg, p, h2)
    return shd.act_residual(x + shd.act_partial_out(ff)), aux, extras


# ---------------------------------------------------------------------------
# full forward (train / prefill)
# ---------------------------------------------------------------------------
def _is_global_arr(cfg, n):
    return jnp.array([cfg.is_global_layer(i) for i in range(n)])


def _cast_layers(layers, dtype):
    """Cast the big matmul weights to the compute dtype so FSDP all-gathers
    move bf16 (fp32 masters stay in the optimizer). Small / numerics-
    sensitive params (norms, decays, SSM projections) stay fp32."""
    keep_exact = {"scale", "bias", "ln_x", "w0", "mu", "u", "mu_c",
                  "a_log", "dt_bias", "wa_decay", "wb_decay", "d_skip",
                  "wdt_down", "wdt_up", "wb_ssm", "wc_ssm", "conv_w",
                  "conv_b", "qnorm", "knorm", "router", "sgate"}

    def f(kp, w):
        parts = [str(getattr(k, "key", k)) for k in kp]
        if w.dtype == jnp.float32 and \
                not any(p in keep_exact or "norm" in p for p in parts):
            return w.astype(dtype)
        return w

    return jax.tree_util.tree_map_with_path(f, layers)


def _embed_tokens(cfg, params, tokens, dtype):
    x = jnp.take(params["embed"].astype(dtype), tokens, axis=0)
    if cfg.tie_embeddings:          # gemma convention: scaled embeddings
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), dtype)
    return x


def encode(cfg: ModelConfig, params: Params, frames) -> jax.Array:
    """Whisper encoder over precomputed conv-frontend frames (B, Senc, d)."""
    dtype = jnp.dtype(cfg.dtype)
    x = frames.astype(dtype) + sinusoidal_positions(
        frames.shape[1], cfg.d_model).astype(dtype)[None]
    enc = params["encoder"]
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1]),
                                 frames.shape[:2])

    def body(x, p):
        h = norm_apply(cfg, x, p["norm1"])
        a, _ = _attn_train(cfg, p["attn"], h, positions, True, causal=False,
                           use_rope=False)
        x = shd.act_residual(x + a @ p["attn"]["wo"].astype(x.dtype))
        h2 = norm_apply(cfg, x, p["norm2"])
        return shd.act_residual(x + _mlp(cfg, p["mlp"], h2)), None

    x, _ = jax.lax.scan(body, x, enc["enc_layers"])
    return norm_apply(cfg, x, enc["final_norm"])


def apply(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
          remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Train/prefill forward. Returns (hidden (B,S,d), moe_aux scalar)."""
    dtype = jnp.dtype(cfg.dtype)
    tokens = shd.act_tokens(batch["tokens"])
    B, S = tokens.shape
    x = _embed_tokens(cfg, params, tokens, dtype)

    if cfg.vision_tokens and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(dtype)
        x = jnp.concatenate([ve, x[:, ve.shape[1]:]], axis=1)

    if "positions" in batch:
        positions = batch["positions"]
    elif cfg.mrope:
        positions = jnp.broadcast_to(jnp.arange(S), (B, 3, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    if cfg.learned_pos:
        x = x + params["pos_embed"][:S].astype(dtype)[None]

    enc_out = None
    if cfg.encoder_decoder:
        enc_out = encode(cfg, params, batch["frames"])

    x = shd.act_residual(x)
    is_global = _is_global_arr(cfg, cfg.num_layers)

    def body(x, scanned):
        p, glob = scanned
        x, aux, _ = _layer_train(cfg, p, x, positions, glob, enc_out)
        return x, aux

    if remat:
        body = jax.checkpoint(body)
    # cast layer weights to the compute dtype BEFORE the scan, so the FSDP
    # all-gathers inside the loop move bf16, not fp32 master weights
    layers = _cast_layers(params["layers"], dtype)
    x, auxs = jax.lax.scan(body, x, (layers, is_global))
    x = norm_apply(cfg, x, params["final_norm"])
    return x, jnp.sum(auxs)


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]):
    """Inference prefill: forward pass that also materializes the cache.

    Returns (last-position logits (B, V), cache pytree with leading L axes).
    """
    dtype = jnp.dtype(cfg.dtype)
    tokens = shd.act_tokens(batch["tokens"])
    B, S = tokens.shape
    x = _embed_tokens(cfg, params, tokens, dtype)
    if cfg.vision_tokens and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(dtype)
        x = jnp.concatenate([ve, x[:, ve.shape[1]:]], axis=1)
    if "positions" in batch:
        positions = batch["positions"]
    elif cfg.mrope:
        positions = jnp.broadcast_to(jnp.arange(S), (B, 3, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.learned_pos:
        x = x + params["pos_embed"][:S].astype(dtype)[None]
    enc_out = encode(cfg, params, batch["frames"]) if cfg.encoder_decoder \
        else None
    x = shd.act_residual(x)
    is_global = _is_global_arr(cfg, cfg.num_layers)

    def body(x, scanned):
        p, glob = scanned
        x, _, extras = _layer_train(cfg, p, x, positions, glob, enc_out,
                                    collect=True)
        return x, extras

    x, cache = jax.lax.scan(body, x, (params["layers"], is_global))
    x = norm_apply(cfg, x, params["final_norm"])
    logits = unembed(cfg, params, x[:, -1:])
    if cfg.encoder_decoder:
        zero = init_cache(cfg, B, S, dtype)
        cache["ck"], cache["cv"] = zero["ck"], zero["cv"]
        cache = prefill_cross(cfg, params, batch["frames"], cache)
    return logits, cache


def unembed(cfg: ModelConfig, params: Params, hidden) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = hidden @ head.astype(hidden.dtype)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# serving: cache init + decode step
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch_size: int, seq_len: int,
               dtype=jnp.bfloat16) -> Params:
    L, B, S = cfg.num_layers, batch_size, seq_len
    KH, hd = cfg.num_kv_heads, cfg.head_dim
    cache: Params = {}
    if cfg.rwkv:
        cache["s"] = jnp.zeros((L, B, cfg.num_heads, hd, hd), jnp.float32)
        cache["shift_t"] = jnp.zeros((L, B, 1, cfg.d_model), dtype)
        cache["shift_c"] = jnp.zeros((L, B, 1, cfg.d_model), dtype)
        return cache
    cache["k"] = jnp.zeros((L, B, S, KH, hd), dtype)
    cache["v"] = jnp.zeros((L, B, S, KH, hd), dtype)
    if cfg.hybrid:
        cache["h"] = jnp.zeros((L, B, cfg.d_model, cfg.ssm_state),
                               jnp.float32)
        cache["conv"] = jnp.zeros((L, B, ssm.CONV_W - 1, cfg.d_model), dtype)
    if cfg.encoder_decoder:
        cache["ck"] = jnp.zeros((L, B, cfg.encoder_seq, KH, hd), dtype)
        cache["cv"] = jnp.zeros((L, B, cfg.encoder_seq, KH, hd), dtype)
    return cache


def prefill_cross(cfg: ModelConfig, params: Params, frames, cache: Params):
    """Whisper: run encoder once, fill per-layer cross K/V caches."""
    enc_out = encode(cfg, params, frames)

    def fill(cache_kv, p):
        dt = enc_out.dtype
        k = (enc_out @ p["cross"]["wk"].astype(dt)).reshape(
            enc_out.shape[0], -1, cfg.num_kv_heads, cfg.head_dim)
        v = (enc_out @ p["cross"]["wv"].astype(dt)).reshape(
            enc_out.shape[0], -1, cfg.num_kv_heads, cfg.head_dim)
        return k, v

    ck, cv = jax.vmap(
        lambda p: fill(None, p), in_axes=(0,))(params["layers"])
    cache = dict(cache)
    cache["ck"], cache["cv"] = ck.astype(cache["ck"].dtype), \
        cv.astype(cache["cv"].dtype)
    return cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens, pos, positions=None, embeds=None):
    """One token for the whole batch. tokens: (B, 1); pos: scalar index.
    `embeds` (B, 1, d) overrides the token embedding (modality frontends).

    Returns (logits (B, 1, V), new_cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    x = embeds.astype(dtype) if embeds is not None else \
        _embed_tokens(cfg, params, tokens, dtype)
    if cfg.learned_pos:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], pos, 1, 0).astype(dtype)[None, 0:1]
    if positions is None:
        shape = (B, 3, 1) if cfg.mrope else (B, 1)
        positions = jnp.full(shape, pos)

    is_global = _is_global_arr(cfg, cfg.num_layers)

    def body(x, scanned):
        p, c, glob = scanned
        new_c = dict(c)
        if cfg.rwkv:
            st = {"shift": c["shift_t"], "s": c["s"]}
            y, st2 = rwkv6.time_mix(norm_apply(cfg, x, p["norm1"]), p["time"],
                                    cfg, state=st, chunked=False)
            x = x + y
            y, sc = rwkv6.channel_mix(norm_apply(cfg, x, p["norm2"]),
                                      p["chan"], cfg, state=c["shift_c"])
            x = x + y
            new_c.update(s=st2["s"], shift_t=st2["shift"], shift_c=sc)
            return x, new_c

        h = norm_apply(cfg, x, p["norm1"])
        q, k, v = _qkv(cfg, p["attn"], h)
        if not cfg.learned_pos:
            q = apply_rope(q, positions, cfg.rope_theta,
                           cfg.mrope_sections if cfg.mrope else None)
            k = apply_rope(k, positions, cfg.rope_theta,
                           cfg.mrope_sections if cfg.mrope else None)
        kc = jax.lax.dynamic_update_slice_in_dim(c["k"], k.astype(c["k"].dtype),
                                                 pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(c["v"], v.astype(c["v"].dtype),
                                                 pos, axis=1)
        new_c.update(k=kc, v=vc)
        a = decode_attention(q, kc, vc, pos, window=cfg.window,
                             is_global=glob)
        a = a.reshape(B, 1, cfg.q_dim)
        if cfg.hybrid:
            s_out, st2 = ssm.ssm_block(
                h, p["ssm"], cfg, state={"conv": c["conv"], "h": c["h"]})
            a = 0.5 * (rmsnorm(a, p["norm_attn_out"]["scale"], cfg.norm_eps)
                       @ p["attn"]["wo"].astype(x.dtype)
                       + rmsnorm(s_out, p["norm_ssm_out"]["scale"],
                                 cfg.norm_eps))
            new_c.update(h=st2["h"], conv=st2["conv"])
        else:
            a = a @ p["attn"]["wo"].astype(x.dtype)
        x = x + a

        if cfg.encoder_decoder:
            hc = norm_apply(cfg, x, p["norm_cross"])
            qc, _, _ = _qkv(cfg, p["cross"], hc)
            ca = decode_attention(qc, c["ck"], c["cv"],
                                  c["ck"].shape[1] - 1, is_global=True)
            x = x + ca.reshape(B, 1, cfg.q_dim) @ p["cross"]["wo"].astype(
                x.dtype)

        h2 = norm_apply(cfg, x, p["norm2"])
        ff, _ = _ffn(cfg, p, h2)
        return x + ff, new_c

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, is_global))
    x = norm_apply(cfg, x, params["final_norm"])
    return unembed(cfg, params, x), new_cache
