"""Mamba-style selective SSM head (hymba's parallel-SSM branch).

State: h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t ; y_t = C_t . h_t + D*x_t
Train/prefill use a chunk-checkpointed scan (boundary states only are saved
for backward); decode carries (conv window, h state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.common import dense_init

CONV_W = 4
SSM_CHUNK = 64


def init_ssm(key, cfg):
    d = cfg.d_model
    di = d                       # inner width = d_model (see DESIGN.md §7)
    N = cfg.ssm_state
    rank = max(8, d // 16)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di)),
        "conv_w": dense_init(ks[1], (CONV_W, di)) * 0.5,
        "conv_b": jnp.zeros((di,)),
        "wdt_down": dense_init(ks[2], (di, rank)),
        "wdt_up": dense_init(ks[3], (rank, di)) * 0.1,
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01))),  # softplus^-1
        "wb_ssm": dense_init(ks[4], (di, N)),
        "wc_ssm": dense_init(ks[5], (di, N)),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "d_skip": jnp.ones((di,)),
        "out_proj": dense_init(ks[6], (di, d)),
    }


def _causal_conv(x, w, b, prev):
    """Depthwise causal conv width CONV_W. prev: (B, CONV_W-1, di)."""
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(CONV_W))
    return jax.nn.silu(out + b)


def _ssm_scan(decay, u, c, h0):
    """decay/u: (B,T,di,N); c: (B,T,N); h0: (B,di,N) -> y (B,T,di), h_f."""
    T = decay.shape[1]
    nc = T // SSM_CHUNK if T % SSM_CHUNK == 0 and T >= SSM_CHUNK else 1
    cs = T // nc

    def inner(h, inp):
        d_t, u_t, c_t = inp
        h = d_t * h + u_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    @jax.checkpoint
    def chunk_body(h, inp):
        d_c, u_c, c_c = inp                      # (B, cs, di, N) / (B, cs, N)
        xs = (jnp.moveaxis(d_c, 1, 0), jnp.moveaxis(u_c, 1, 0),
              jnp.moveaxis(c_c, 1, 0))
        h, ys = jax.lax.scan(inner, h, xs)
        return h, jnp.moveaxis(ys, 0, 1)

    def chunks(a):
        return jnp.moveaxis(
            a.reshape(a.shape[0], nc, cs, *a.shape[2:]), 1, 0)

    h_f, ys = jax.lax.scan(chunk_body, h0, (chunks(decay), chunks(u),
                                            chunks(c)))
    ys = jnp.moveaxis(ys, 0, 1).reshape(decay.shape[0], T, -1)
    return ys, h_f


def ssm_block(x, p, cfg, state=None):
    """x: (B, T, d). state: None or dict(conv=(B,3,di), h=(B,di,N))."""
    B, T, d = x.shape
    N = cfg.ssm_state
    dtype = x.dtype
    xz = x @ p["in_proj"].astype(dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_prev = state["conv"].astype(dtype) if state else \
        jnp.zeros((B, CONV_W - 1, xi.shape[-1]), dtype)
    xc = _causal_conv(xi, p["conv_w"].astype(dtype), p["conv_b"].astype(dtype),
                      conv_prev)
    xc32 = xc.astype(jnp.float32)
    dt = jax.nn.softplus(
        (xc32 @ p["wdt_down"]) @ p["wdt_up"] + p["dt_bias"])     # (B,T,di)
    Bm = xc32 @ p["wb_ssm"]                                      # (B,T,N)
    Cm = xc32 @ p["wc_ssm"]
    A = -jnp.exp(p["a_log"])                                     # (di,N)
    decay = jnp.exp(dt[..., None] * A)                           # (B,T,di,N)
    u = (dt * xc32)[..., None] * Bm[:, :, None, :]
    h0 = state["h"] if state else jnp.zeros((B, xc.shape[-1], N), jnp.float32)
    y, h_f = _ssm_scan(decay, u, Cm, h0)
    y = y + xc32 * p["d_skip"]
    y = (y.astype(dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dtype)
    new_state = {"conv": jnp.concatenate([conv_prev, xi], 1)[:, -(CONV_W - 1):],
                 "h": h_f}
    return out, new_state
