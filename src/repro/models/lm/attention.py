"""Attention: flash-style chunked jnp attention (custom VJP) for
train/prefill and KV-cache attention for decode.

`flash_attention` scans KV chunks with an online softmax so the (S, S) score
matrix never materializes, and carries a *custom VJP*: the backward pass
recomputes per-chunk probabilities from (q, k, v, out, lse) instead of
letting autodiff save every chunk's softmax state — this is what keeps the
32k-prefill / 4k-train cells inside 16 GiB/chip. The Pallas kernel
(`repro.kernels.flash_attention`) mirrors the same computation for real TPUs
and is validated against `attention_ref`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _mask(q_pos, kv_pos, *, causal, window, is_global):
    """(Sq, C) boolean mask. `is_global` may be a traced scalar."""
    ok = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        ok = ok & (kv_pos[None, :] <= q_pos[:, None])
    win_ok = (q_pos[:, None] - kv_pos[None, :]) < window
    ok = ok & (is_global | win_ok)
    return ok


def attention_ref(q, k, v, *, causal=True, window=1 << 30, is_global=True,
                  q_offset=0):
    """Naive O(S^2) oracle. q (B,Sq,H,D); k/v (B,Skv,KH,D)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qr = q.reshape(B, Sq, KH, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(k.shape[1])
    m = _mask(q_pos, kv_pos, causal=causal, window=window, is_global=is_global)
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash attention with custom VJP
# ---------------------------------------------------------------------------
def _fwd_scan(q, k, v, is_global, *, causal, window, q_offset, chunk):
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    n_chunks = Skv // chunk
    qf = q.astype(jnp.float32).reshape(B, Sq, KH, G, D)
    scale = 1.0 / np.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, KH, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, KH, D), 1, 0)

    def body(carry, inp):
        m_i, l_i, acc = carry
        kci, vci, c_idx = inp
        kv_pos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf,
                       kci.astype(jnp.float32)) * scale
        msk = _mask(q_pos, kv_pos, causal=causal, window=window,
                    is_global=is_global)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_i * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vci.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, KH, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Sq, D), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    l_safe = jnp.maximum(l_f, 1e-30)
    out = acc / l_safe[..., None]                       # (B,KH,G,Sq,D)
    lse = m_f + jnp.log(l_safe)
    out_b = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, H, D)
    return out_b.astype(q.dtype), out, lse


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash(q, k, v, is_global, causal, window, q_offset, chunk):
    out, _, _ = _fwd_scan(q, k, v, is_global, causal=causal, window=window,
                          q_offset=q_offset, chunk=chunk)
    return out


def _flash_fwd(q, k, v, is_global, causal, window, q_offset, chunk):
    out, out32, lse = _fwd_scan(q, k, v, is_global, causal=causal,
                                window=window, q_offset=q_offset, chunk=chunk)
    return out, (q, k, v, is_global, out32, lse)


def _flash_bwd(causal, window, q_offset, chunk, res, dout):
    q, k, v, is_global, out32, lse = res
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    n_chunks = Skv // chunk
    scale = 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, KH, G, D)
    do = dout.astype(jnp.float32).reshape(B, Sq, KH, G, D)
    do = jnp.transpose(do, (0, 2, 3, 1, 4))            # (B,KH,G,Sq,D)
    delta = jnp.sum(do * out32, axis=-1)               # (B,KH,G,Sq)
    q_pos = q_offset + jnp.arange(Sq)
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, KH, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, KH, D), 1, 0)

    def body(dq_acc, inp):
        kci, vci, c_idx = inp
        kv_pos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf,
                       kci.astype(jnp.float32)) * scale
        msk = _mask(q_pos, kv_pos, causal=causal, window=window,
                    is_global=is_global)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                 # (B,KH,G,Sq,C)
        dv = jnp.einsum("bkgqs,bkgqd->bskd", p, do)
        dp = jnp.einsum("bkgqd,bskd->bkgqs", do, vci.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bkgqs,bskd->bkgqd", ds,
                                     kci.astype(jnp.float32))
        dk = jnp.einsum("bkgqs,bqkgd->bskd", ds, qf)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, KH, G, Sq, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0,
                                  (kc, vc, jnp.arange(n_chunks)))
    dq = jnp.transpose(dq, (0, 3, 1, 2, 4)).reshape(B, Sq, H, D)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Skv, KH, D)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Skv, KH, D)
    dg = np.zeros(np.shape(is_global), jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dg)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window=1 << 30, is_global=True,
                    q_offset=0, chunk=512):
    """Online-softmax attention over KV chunks; O(Sq*chunk) live memory in
    both forward and backward."""
    Skv = k.shape[1]
    if Skv % chunk != 0:
        chunk = Skv                                   # tiny/smoke shapes
    if isinstance(is_global, bool):
        is_global = jnp.asarray(is_global)
    return _flash(q, k, v, is_global, causal, window, q_offset, chunk)


def decode_attention(q, k_cache, v_cache, pos, *, window=1 << 30,
                     is_global=True):
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    q: (B, 1, H, D); caches: (B, S, KH, D); pos: scalar int (current index).
    Softmax reductions over the cache axis are written explicitly so the SPMD
    partitioner inserts the flash-decoding style partial max / denominator
    all-reduces when the cache is sharded over `model`.
    """
    B, _, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    qf = q.astype(jnp.float32).reshape(B, KH, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    s = s / np.sqrt(D)
    kv_pos = jnp.arange(S)
    ok = kv_pos <= pos
    ok = ok & (is_global | ((pos - kv_pos) < window))
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / denom, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)
