"""RWKV6 "Finch" token mixing: data-dependent decay linear attention.

Two equivalent implementations:
  - ``wkv6_scan``  — exact per-timestep recurrence (oracle; decode path)
  - ``wkv6_chunked`` — chunked matmul form used for train/prefill: chunks of
    ``CHUNK`` steps are processed with dense (C,C) intra-chunk matmuls and a
    scanned inter-chunk state, with ``jax.checkpoint`` on the chunk body so
    the backward pass stores only chunk-boundary states. The Pallas kernel
    (`repro.kernels.rwkv6_chunk`) mirrors this form.

Decay logits are clamped to [LOGW_MIN, LOGW_MAX] so the factored
exp(cum_prev[t] - cum[s]) intra-chunk term stays inside fp32 range
(|LOGW_MIN| * CHUNK < 88). Simplification vs the released model: the
r/k/v/g mix coefficients are static per-channel (v5-style) while the decay
keeps the v6 data-dependent LoRA; recorded in DESIGN.md §7.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.common import dense_init

CHUNK = 16
LOGW_MIN = -5.0
LOGW_MAX = -1e-4


def init_time_mix(key, cfg):
    d = cfg.d_model
    H, N = cfg.num_heads, cfg.head_dim
    lora = 64
    ks = jax.random.split(key, 10)
    return {
        "mu": jax.random.uniform(ks[0], (5, d)),          # r,k,v,g,w lerp
        "w0": jnp.zeros((d,)) - 0.6,                       # base decay logit
        "wa_decay": dense_init(ks[1], (d, lora)) * 0.1,
        "wb_decay": dense_init(ks[2], (lora, d)) * 0.1,
        "wr_t": dense_init(ks[3], (d, H * N)),
        "wk_t": dense_init(ks[4], (d, H * N)),
        "wv_t": dense_init(ks[5], (d, H * N)),
        "wg_t": dense_init(ks[6], (d, H * N)),
        "u": jax.random.normal(ks[7], (H, N)) * 0.1,       # bonus
        "ln_x": jnp.ones((H, N)),                          # per-head norm
        "wo": dense_init(ks[8], (H * N, d)),
    }


def init_channel_mix(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_c": jax.random.uniform(ks[0], (2, d)),         # k, r lerp
        "wck": dense_init(ks[1], (d, ff)),
        "wcv": dense_init(ks[2], (ff, d)),
        "wcr": dense_init(jax.random.fold_in(key, 7), (d, d)),
    }


def token_shift(x, prev):
    """x: (B, T, d); prev: (B, 1, d) last token of previous segment."""
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------
def wkv6_scan(r, k, v, logw, u, s0):
    """Exact recurrence. r/k/v/logw: (B,T,H,N); u: (H,N); s0: (B,H,N,N).

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ; out_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    Returns (out (B,T,H,N), s_final).
    """
    w = jnp.exp(logw.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp                                    # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]                # (B,H,N,N)
        out = jnp.einsum("bhn,bhnm->bhm", rt, s + u[..., None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    s_f, out = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), s_f


def wkv6_chunked(r, k, v, logw, u, s0, chunk=CHUNK):
    """Chunked matmul form (see module docstring). Same signature as scan."""
    B, T, H, N = r.shape
    if T % chunk != 0:
        return wkv6_scan(r, k, v, logw, u, s0)
    nc = T // chunk

    def reshape(a):
        return a.astype(jnp.float32).reshape(B, nc, chunk, H, N)

    rc, kc, vc, wc = map(reshape, (r, k, v, logw))
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)

    @jax.checkpoint
    def body(s, inp):
        rt, kt, vt, lw = inp                                    # (B,C,H,N)
        cum = jnp.cumsum(lw, axis=1)                            # inclusive
        cum_prev = cum - lw
        q_dec = rt * jnp.exp(cum_prev)                          # <= |r|
        k_dec = kt * jnp.exp(-cum)                              # <= e^{|LOGW_MIN|*C}
        scores = jnp.einsum("bihn,bjhn->bhij", q_dec, k_dec) * tri
        diag = jnp.einsum("bihn,hn,bihn->bhi", rt, u, kt)
        scores = scores + diag[..., :, None] * jnp.eye(chunk, dtype=jnp.float32)
        out = jnp.einsum("bhij,bjhn->bihn", scores, vt)
        out = out + jnp.einsum("bihn,bhnm->bihm", q_dec, s)
        last = cum[:, -1]                                       # (B,H,N)
        k_rem = kt * jnp.exp(last[:, None] - cum)               # <= |k|
        s = jnp.exp(last)[..., None] * s + \
            jnp.einsum("bjhn,bjhm->bhnm", k_rem, vt)
        return s, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, wc))
    s_f, out = jax.lax.scan(body, s0.astype(jnp.float32), xs)
    out = jnp.moveaxis(out, 0, 1).reshape(B, T, H, N)
    return out.astype(r.dtype), s_f


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _project(x, p, cfg, shift_prev):
    B, T, d = x.shape
    H, N = cfg.num_heads, cfg.head_dim
    xs = token_shift(x, shift_prev)
    mu = p["mu"].astype(x.dtype)
    mix = lambda i: x * mu[i] + xs * (1.0 - mu[i])
    r = (mix(0) @ p["wr_t"].astype(x.dtype)).reshape(B, T, H, N)
    k = (mix(1) @ p["wk_t"].astype(x.dtype)).reshape(B, T, H, N)
    v = (mix(2) @ p["wv_t"].astype(x.dtype)).reshape(B, T, H, N)
    g = jax.nn.silu(mix(3) @ p["wg_t"].astype(x.dtype))
    xw = mix(4).astype(jnp.float32)
    lora = jnp.tanh(xw @ p["wa_decay"]) @ p["wb_decay"]
    logw = -jnp.exp(p["w0"] + lora)                             # (B,T,d) < 0
    logw = jnp.clip(logw, LOGW_MIN, LOGW_MAX).reshape(B, T, H, N)
    return r, k, v, g, logw


def _head_norm(out, p, cfg):
    B, T, H, N = out.shape
    o32 = out.astype(jnp.float32)
    var = jnp.mean(o32 * o32, axis=-1, keepdims=True)
    o32 = o32 * jax.lax.rsqrt(var + 64e-5) * p["ln_x"]
    return o32.reshape(B, T, H * N)


def time_mix(x, p, cfg, state=None, chunked=True):
    """state: None (train, zeros) or dict(shift=(B,1,d), s=(B,H,N,N))."""
    B, T, d = x.shape
    H, N = cfg.num_heads, cfg.head_dim
    shift_prev = state["shift"] if state else jnp.zeros((B, 1, d), x.dtype)
    s0 = state["s"] if state else jnp.zeros((B, H, N, N), jnp.float32)
    r, k, v, g, logw = _project(x, p, cfg, shift_prev)
    fn = wkv6_chunked if chunked else wkv6_scan
    out, s_f = fn(r, k, v, logw, p["u"].astype(jnp.float32), s0)
    out = _head_norm(out, p, cfg).astype(x.dtype) * g
    y = out @ p["wo"].astype(x.dtype)
    new_state = {"shift": x[:, -1:], "s": s_f}
    return y, new_state


def channel_mix(x, p, cfg, state=None):
    B, T, d = x.shape
    shift_prev = state if state is not None else jnp.zeros((B, 1, d), x.dtype)
    xs = token_shift(x, shift_prev)
    mu = p["mu_c"].astype(x.dtype)
    xk = x * mu[0] + xs * (1.0 - mu[0])
    xr = x * mu[1] + xs * (1.0 - mu[1])
    kk = jnp.square(jax.nn.relu(xk @ p["wck"].astype(x.dtype)))
    rr = jax.nn.sigmoid(xr @ p["wcr"].astype(x.dtype))
    return rr * (kk @ p["wcv"].astype(x.dtype)), x[:, -1:]
