"""Shared LM building blocks: norms, activations, RoPE/M-RoPE, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """LeCun-normal in fp32 (params are stored fp32, computed in bf16)."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    return jax.random.normal(key, shape, dtype) * (1.0 / np.sqrt(fan_in))


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return ((1.0 + scale.astype(jnp.float32)) * out).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def norm_apply(cfg, x, p):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def norm_init(cfg, d):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}
    return {"scale": jnp.zeros((d,))}   # rmsnorm stores (scale-1)


def activation(name):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta, mrope_sections=None):
    """x: (B, S, H, D). positions: (B, S) or (B, 3, S) for M-RoPE.

    M-RoPE (qwen2-vl): the D/2 rotary frequencies are split into
    `mrope_sections` groups, each driven by the temporal / height / width
    position component respectively.
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)          # (half,)
    if positions.ndim == 3:                         # M-RoPE
        sections = mrope_sections
        assert sections is not None and sum(sections) == half
        comp = jnp.concatenate(
            [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
        pos = jnp.transpose(positions.astype(jnp.float32), (0, 2, 1))  # (B,S,3)
        pos = jnp.take(pos, comp, axis=-1)          # (B, S, half)
        angles = pos * freqs[None, None, :]
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]            # (B, S, 1, half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq, d):
    """Whisper-style sinusoidal embeddings (fp32, (seq, d))."""
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * dim / d)
    return jnp.asarray(
        np.concatenate([np.sin(angle), np.cos(angle)], axis=1), jnp.float32)
