"""End-to-end driver: train GraphSAGE with COMM-RAND for a few hundred
steps on a reddit-like synthetic graph, with checkpointing + early stopping
— the paper's training pipeline as a user would run it.

Batch construction is all `repro.batching`: the policy comes from the
registry, caps from the cached `CapsCalibrator`, and batches from the
trainer's resumable `BatchStream` — rerun with the same --ckpt-dir after an
interruption and training continues bit-exactly from the saved cursor.

    PYTHONPATH=src python examples/train_gnn_commrand.py \
        --dataset reddit-like --policy comm_rand --mix 0.125 --p 1.0
"""
import argparse

from repro.batching import CapsCalibrator, make_policy
from repro.configs.base import GNNConfig, TrainConfig
from repro.core.reorder import prepare
from repro.graphs import synthetic
from repro.train.gnn_loop import GNNTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="reddit-like",
                    choices=sorted(synthetic.DATASETS))
    ap.add_argument("--policy", default="comm_rand",
                    choices=["rand", "norand", "comm_rand"])
    ap.add_argument("--mix", type=float, default=0.125)
    ap.add_argument("--p", type=float, default=1.0)
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--oracle-communities", action="store_true")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint + resume (cursor travels with weights)")
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="steps between checkpoints (with --ckpt-dir)")
    ap.add_argument("--caps-cache", default=None,
                    help="JSON file memoizing calibrated caps across runs")
    ap.add_argument("--cache", default=None,
                    choices=["degree_hot", "community_freq",
                             "presampled_freq", "dynamic",
                             "dynamic:degree_hot", "dynamic:community_freq",
                             "dynamic:presampled_freq"],
                    help="device-resident feature cache (repro.featcache): "
                         "a static admission policy, or 'dynamic[:seed-"
                         "admission]' for the on-device CLOCK loop that "
                         "re-admits at every epoch boundary — hit rates "
                         "and refill churn print per epoch")
    ap.add_argument("--cache-frac", type=float, default=0.2,
                    help="cache capacity as a fraction of N (with --cache)")
    ap.add_argument("--pipeline", default="sync",
                    choices=["sync", "async"],
                    help="batch pipeline: 'sync' = classic BatchStream; "
                         "'async' = repro.pipeline's depth-2 background "
                         "prefetcher over the fused on-device builder "
                         "(bit-exact same batches, overlapped with the "
                         "train step)")
    args = ap.parse_args()

    g = prepare(synthetic.load(args.dataset),
                oracle=args.oracle_communities)
    pol = make_policy(args.policy, mix=args.mix, p=args.p)
    cfg = GNNConfig(f"sage-{args.dataset}", "sage", args.layers, args.hidden,
                    g.feat_dim, g.num_classes,
                    fanout=(10,) * args.layers)
    tcfg = TrainConfig(batch_size=args.batch_size, max_epochs=args.epochs)
    print(f"policy: {pol.describe()}  graph: {g.name} ({g.num_nodes} nodes)")
    tr = GNNTrainer(g, cfg, tcfg, pol, seed=0, ckpt_dir=args.ckpt_dir,
                    ckpt_every=args.ckpt_every,
                    calibrator=CapsCalibrator(cache_path=args.caps_cache),
                    cache=args.cache, cache_frac=args.cache_frac,
                    pipeline=args.pipeline).warmup()
    print(f"calibrated caps: {tr.caps}")
    if tr.cache is not None:
        print(f"feature cache: {tr.cache.describe()}")
    if tr.global_step:
        print(f"resumed at step {tr.global_step} "
              f"(cursor: {tr.stream.cursor.state()})")
    res = tr.fit(verbose=True)
    print(f"\nbest val_acc={res.val_acc:.4f} test_acc={res.test_acc:.4f} "
          f"epochs={res.epochs_to_converge} "
          f"per_epoch={res.per_epoch_time_s:.2f}s "
          f"total={res.total_time_s:.1f}s"
          + (f" cache_hit={res.cache_hit_rate:.3f} "
             f"refills={res.cache_refills}" if res.cache else ""))


if __name__ == "__main__":
    main()
