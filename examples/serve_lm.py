"""Batched LM serving on CPU: prefill a batch of prompts into a KV cache,
then decode tokens step by step (reduced config of any assigned arch).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import LM_ARCHS, get_config
from repro.launch.specs import materialize, prefill_batch_specs
from repro.models.lm import transformer
from repro.train.train_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=list(LM_ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model})")
    params = transformer.init(cfg, jax.random.key(0), max_seq=256)

    total = args.prompt_len + args.tokens
    batch = materialize(prefill_batch_specs(cfg, args.batch,
                                            args.prompt_len))
    batch["tokens"] = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size,
        jnp.int32)

    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill({args.prompt_len} tok x {args.batch}): "
          f"{(time.perf_counter() - t0) * 1e3:.1f}ms")

    # grow the cache to the full serving length
    full = transformer.init_cache(cfg, args.batch, total, jnp.bfloat16)
    if not cfg.rwkv:
        full["k"] = jax.lax.dynamic_update_slice_in_dim(
            full["k"], cache["k"].astype(full["k"].dtype), 0, axis=2)
        full["v"] = jax.lax.dynamic_update_slice_in_dim(
            full["v"], cache["v"].astype(full["v"].dtype), 0, axis=2)
        for key in ("h", "conv", "ck", "cv"):
            if key in cache:
                full[key] = cache[key].astype(full[key].dtype)
        cache = full

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for t in range(args.tokens):
        logits, cache = decode(params, cache, tok, args.prompt_len + t)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"decoded {args.tokens} tokens x {args.batch} seqs in "
          f"{dt * 1e3:.1f}ms ({args.tokens * args.batch / dt:.0f} tok/s)")
    print("sampled ids (greedy):", toks[0].tolist())


if __name__ == "__main__":
    main()
