"""Quickstart: COMM-RAND vs uniform-random mini-batching in ~60 seconds.

Generates a community-structured synthetic graph, preprocesses it
(community detection -> RABBIT-style reorder -> intra-first rows), then
trains GraphSAGE under two `repro.batching` policies and prints the
paper's four metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.batching import available_policies, make_policy
from repro.configs.base import GNNConfig, TrainConfig
from repro.core.reorder import prepare
from repro.graphs import synthetic
from repro.train.gnn_loop import train_once


def main():
    print("== generating community-structured graph (tiny SBM) ==")
    g = prepare(synthetic.load("tiny"), oracle=False)   # runs Louvain
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, "
          f"{g.communities.max() + 1} detected communities")
    print(f"registered batch policies: {', '.join(available_policies())}")

    cfg = GNNConfig("sage-quickstart", "sage", 2, 64, g.feat_dim,
                    g.num_classes, fanout=(10, 10))
    tcfg = TrainConfig(batch_size=512, max_epochs=15)

    rows = []
    for pol in (make_policy("rand"),                      # baseline
                make_policy("comm_rand", mix=0.125, p=1.0)):  # paper §6.1.3
        r = train_once(g, cfg, pol, tcfg, seed=0)
        rows.append(r)
        print(f"{r.policy:28s} val_acc={r.val_acc:.4f} "
              f"epochs={r.epochs_to_converge} "
              f"per_epoch={r.per_epoch_time_s * 1e3:.0f}ms "
              f"unique_nodes/batch={r.mean_unique_nodes:.0f}")
    base, best = rows
    print(f"\nCOMM-RAND: {base.per_epoch_time_s / best.per_epoch_time_s:.2f}x"
          f" per-epoch speedup, "
          f"{base.mean_unique_nodes / best.mean_unique_nodes:.2f}x smaller"
          f" working set, val acc delta "
          f"{(base.val_acc - best.val_acc) * 100:+.2f}pp")


if __name__ == "__main__":
    main()
