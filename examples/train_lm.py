"""Train a reduced LM arch for a few hundred steps with the fault-tolerant
loop (checkpoint/resume, straggler monitor, optional int8 grad compression).

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-72b --steps 200
"""
import argparse

from repro.configs.base import TrainConfig
from repro.configs.registry import LM_ARCHS, get_config
from repro.data.pipeline import BlockShuffler, LMStream, SyntheticTokens
from repro.train.lm_loop import LMTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=list(LM_ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--shuffle-mode", default="block",
                    choices=["rand", "block", "none"],
                    help="block = COMM-RAND-style constrained shuffle")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    tcfg = TrainConfig(learning_rate=args.lr, remat=False,
                       grad_compression=args.compress_grads)
    corpus = SyntheticTokens(cfg.vocab_size, num_docs=2048,
                             doc_len=args.seq * 2)
    stream = LMStream(corpus, args.batch, args.seq,
                      BlockShuffler(corpus.num_docs, 64,
                                    mode=args.shuffle_mode))
    tr = LMTrainer(cfg, tcfg, stream, ckpt_dir=args.ckpt_dir)
    if tr.step:
        print(f"resumed from step {tr.step}")
    r = tr.run(args.steps)
    print(f"arch={args.arch} steps={args.steps}: "
          f"loss {r['loss_first']:.3f} -> {r['loss_last']:.3f} "
          f"(stragglers: {r['straggler_fraction'] * 100:.1f}%)")


if __name__ == "__main__":
    main()
