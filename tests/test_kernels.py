"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
hypothesis shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.gather_agg.ops import gather_agg, resolve_agg_impl
from repro.kernels.gather_agg.ref import gather_agg_ref
from repro.kernels.gather_mean.ops import gather_mean
from repro.kernels.gather_mean.ref import gather_mean_ref
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.moe_gmm.ref import moe_gmm_ref
from repro.kernels.rwkv6_chunk.ops import wkv6_op
from repro.kernels.rwkv6_chunk.ref import wkv6_ref
from repro.models.lm.attention import attention_ref, flash_attention
from repro.models.lm.rwkv6 import wkv6_chunked, wkv6_scan


# ---------------------------------------------------------------------------
# gather_agg (fused gather + weighted reduce, custom VJP)
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([16, 50, 200]), d=st.sampled_from([5, 17, 64]),
       r=st.sampled_from([1, 4, 10]), f=st.sampled_from([8, 128, 96]),
       bd=st.sampled_from([1, 4, 8]), seed=st.integers(0, 20))
def test_gather_agg_matches_ref(n, d, r, f, bd, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(ks[0], (n, f), jnp.float32)
    idx = jax.random.randint(ks[1], (d, r), 0, n)
    w = jax.random.normal(ks[2], (d, r), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(gather_agg(x, idx, w, impl="pallas", block_dst=bd)),
        np.asarray(gather_agg_ref(x, idx, w)), rtol=1e-5, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(n=st.sampled_from([20, 80]), d=st.sampled_from([7, 33]),
       r=st.sampled_from([3, 6]), f=st.sampled_from([16, 64]),
       seed=st.integers(0, 20))
def test_gather_agg_grads_match_ref(n, d, r, f, seed):
    """Backward Pallas pair (scatter-add dx, gather-dot dw) vs autodiff of
    the jnp oracle."""
    ks = jax.random.split(jax.random.key(seed), 4)
    x = jax.random.normal(ks[0], (n, f), jnp.float32)
    idx = jax.random.randint(ks[1], (d, r), 0, n)
    w = jax.random.normal(ks[2], (d, r), jnp.float32)
    cot = jax.random.normal(ks[3], (d, f), jnp.float32)

    def loss(impl):
        return jax.grad(
            lambda x, w: (gather_agg(x, idx, w, impl=impl) * cot).sum(),
            argnums=(0, 1))(x, w)

    (dxp, dwp), (dxj, dwj) = loss("pallas"), loss("jnp")
    np.testing.assert_allclose(np.asarray(dxp), np.asarray(dxj),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dwp), np.asarray(dwj),
                               rtol=1e-4, atol=1e-4)


def test_gather_agg_repeated_rows_scatter_add():
    """Many edges hitting the SAME source row must accumulate, not race."""
    x = jnp.ones((4, 32))
    idx = jnp.zeros((6, 5), jnp.int32)            # every edge -> row 0
    w = jnp.ones((6, 5))
    cot = jnp.ones((6, 32))
    dx = jax.grad(lambda x: (gather_agg(x, idx, w, impl="pallas")
                             * cot).sum())(x)
    assert float(dx[0, 0]) == 30.0                # 6 dst x 5 slots
    assert float(jnp.abs(dx[1:]).max()) == 0.0    # untouched rows stay zero


def test_resolve_agg_impl():
    assert resolve_agg_impl("jnp") == "jnp"
    assert resolve_agg_impl("pallas") == "pallas"
    # this suite runs on CPU (conftest pins the platform)
    assert resolve_agg_impl("auto") == "jnp"
    with pytest.raises(ValueError):
        resolve_agg_impl("nope")


# ---------------------------------------------------------------------------
# gather_mean (deprecated shim over gather_agg)
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([16, 50, 200]), d=st.sampled_from([8, 33]),
       r=st.sampled_from([1, 4, 10]),
       f=st.sampled_from([128, 256, 96]),
       dense=st.booleans(), seed=st.integers(0, 20))
def test_gather_mean_matches_ref(n, d, r, f, dense, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(ks[0], (n, f), jnp.float32)
    idx = jax.random.randint(ks[1], (d, r), 0, n)
    mask = jnp.ones((d, r), bool) if dense else \
        jax.random.bernoulli(ks[2], 0.7, (d, r))
    np.testing.assert_allclose(
        np.asarray(gather_mean(x, idx, mask)),
        np.asarray(gather_mean_ref(x, idx, mask)), rtol=1e-5, atol=1e-5)


def test_gather_mean_all_masked_row_is_zero():
    x = jnp.ones((8, 128))
    idx = jnp.zeros((2, 4), jnp.int32)
    mask = jnp.zeros((2, 4), bool)
    assert float(jnp.abs(gather_mean(x, idx, mask)).max()) == 0.0


# ---------------------------------------------------------------------------
# flash attention (kernel + custom-vjp jnp twin)
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([1, 2]), s=st.sampled_from([32, 64]),
       h=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
       d=st.sampled_from([16, 32]),
       causal=st.booleans(),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 20))
def test_flash_kernel_matches_ref(b, s, h, g, d, causal, dtype, seed):
    kh = h // g
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kh, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kh, d)).astype(dtype)
    out = flash_attention_op(q, k, v, causal=causal, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_sliding_window():
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    out = flash_attention_op(q, k, v, causal=True, window=16,
                             is_global=False, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=True, window=16, is_global=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_custom_vjp_grads_match_ref():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    f = lambda q, k, v: (flash_attention(q, k, v, chunk=16) ** 2).sum()
    r = lambda q, k, v: (attention_ref(q, k, v) ** 2).sum()
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(b=st.sampled_from([1, 2]), t=st.sampled_from([16, 64]),
       h=st.sampled_from([1, 2]), n=st.sampled_from([8, 16]),
       seed=st.integers(0, 20))
def test_wkv6_kernel_matches_scan(b, t, h, n, seed):
    ks = jax.random.split(jax.random.key(seed), 5)
    r = jax.random.normal(ks[0], (b, t, h, n))
    k = jax.random.normal(ks[1], (b, t, h, n))
    v = jax.random.normal(ks[2], (b, t, h, n))
    logw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (b, t, h, n)) * 0.5),
                    -5.0, -1e-4)
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    out = wkv6_op(r, k, v, logw, u)
    ref = wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_chunked_matches_scan_with_state():
    ks = jax.random.split(jax.random.key(9), 5)
    B, T, H, N = 2, 48, 2, 16
    r = jax.random.normal(ks[0], (B, T, H, N))
    k = jax.random.normal(ks[1], (B, T, H, N))
    v = jax.random.normal(ks[2], (B, T, H, N))
    logw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, T, H, N))),
                    -5.0, -1e-4)
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    s0 = jax.random.normal(jax.random.key(10), (B, H, N, N)) * 0.1
    oc, sc = wkv6_chunked(r, k, v, logw, u, s0, chunk=16)
    os_, ss = wkv6_scan(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(os_),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(ss),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# moe gmm
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(e=st.sampled_from([1, 4]), c=st.sampled_from([128, 256]),
       d=st.sampled_from([128, 256]), f=st.sampled_from([128, 384]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 10))
def test_moe_gmm_matches_ref(e, c, d, f, dtype, seed):
    ks = jax.random.split(jax.random.key(seed), 2)
    x = jax.random.normal(ks[0], (e, c, d)).astype(dtype)
    w = jax.random.normal(ks[1], (e, d, f)).astype(dtype)
    out = moe_gmm(x, w)
    ref = moe_gmm_ref(x, w)
    tol = 2e-1 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# model aggregation: agg_impl="pallas" vs agg_impl="jnp" (fwd + bwd)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model", ["sage", "gcn", "gat"])
def test_apply_gnn_pallas_matches_jnp(tiny_graph, model):
    import dataclasses

    from repro.configs.base import GNNConfig
    from repro.core import minibatch as mb
    from repro.graphs.csr import DeviceGraph
    from repro.models.gnn.models import apply_gnn, init_gnn
    from repro.train.losses import gnn_softmax_ce

    g = tiny_graph
    gdev = DeviceGraph.from_graph(g)
    feats = jnp.asarray(g.features)
    cfg_j = GNNConfig("t", model, 2, 32, g.feat_dim, g.num_classes,
                      fanout=(4, 4), dropout=0.0, agg_impl="jnp")
    cfg_p = dataclasses.replace(cfg_j, agg_impl="pallas")
    params = init_gnn(cfg_j, jax.random.key(1))
    batch = mb.build_batch(jax.random.key(2), gdev,
                           jnp.asarray(g.train_ids[:32], jnp.int32),
                           jnp.asarray(g.labels), (4, 4), (256, 384), 0.9)

    out_j = apply_gnn(cfg_j, params, batch, feats, gdev.degrees,
                      feats_global=True)
    out_p = apply_gnn(cfg_p, params, batch, feats, gdev.degrees,
                      feats_global=True)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_j),
                               rtol=1e-5, atol=1e-5)

    def loss(p, cfg):
        lg = apply_gnn(cfg, p, batch, feats, gdev.degrees,
                       feats_global=True)
        return gnn_softmax_ce(lg, batch.labels,
                              batch.label_mask.astype(jnp.float32))

    gj = jax.grad(loss)(params, cfg_j)
    gp = jax.grad(loss)(params, cfg_p)
    for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gj)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
