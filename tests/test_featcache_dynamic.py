"""Dynamic CLOCK admission (`repro.featcache.dynamic`): the extended
device counters, the epoch-boundary refill against its pure-numpy oracle
(slot-for-slot, including hand position and tie-breaking), the trainer
integration (bit-identical losses with an evolving cache), and bit-exact
checkpoint/resume of the full `DynamicCacheState`.

Invariant -> test map (mirrored in the README testing section):
  counters == mirror .......... test_ref_updates_matches_numpy_mirror
  refill == numpy oracle ...... test_refill_matches_numpy_oracle
                                test_pallas_counter_pipeline_matches_numpy
  residency consistency ....... test_refill_preserves_residency_invariants
  tie-breaking (shared rule) .. test_refill_tie_breaking
  read-path purity ............ test_trainer_dynamic_cache_bit_identical
  epoch-boundary adaptation ... test_trainer_dynamic_cache_adapts
  eval isolation .............. test_eval_does_not_feed_admission
  dynamic <= static ........... test_dynamic_not_worse_than_static_replay
  bit-exact resume ............ test_resume_dynamic_cache_bit_exact
"""
import tempfile
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import featcache
from repro.batching import CapsCalibrator, make_policy
from repro.batching.policy import CommRandPolicy
from repro.configs.base import GNNConfig, TrainConfig
from repro.featcache import dynamic
from repro.featcache.dynamic import DynamicCacheState
from repro.kernels.gather_cached.ops import cache_ref_updates, gather_cached
from repro.train.gnn_loop import GNNTrainer


def _random_state(rng, n, c, f, max_freq=4):
    """A mid-epoch DynamicCacheState with random bits/frequencies (small
    `max_freq` forces plenty of TIES) + the matching numpy feats."""
    feats = rng.normal(size=(n, f)).astype(np.float32)
    ids = np.sort(rng.choice(n, size=c, replace=False))
    pos = np.full(n, -1, np.int32)
    pos[ids] = np.arange(c, dtype=np.int32)
    state = DynamicCacheState(
        cache=jnp.asarray(feats[ids]),
        pos=jnp.asarray(pos),
        slot_ids=jnp.asarray(ids.astype(np.int32)),
        refbit=jnp.asarray(rng.integers(0, 2, c).astype(np.int32)),
        slot_freq=jnp.asarray(rng.integers(0, max_freq, c).astype(np.int32)),
        freq=jnp.asarray(rng.integers(0, max_freq, n).astype(np.int32)),
        hand=jnp.asarray(int(rng.integers(0, c)), jnp.int32),
        capacity=c, policy="test")
    return state, feats


def _np_states_equal(a, b):
    return all(np.array_equal(a[k], b[k]) for k in a) and a.keys() == b.keys()


# ---------------------------------------------------------------------------
# extended counters: device == numpy mirror, consistent with cache_stats
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([16, 50, 200]), c=st.sampled_from([1, 7, 40]),
       m=st.sampled_from([4, 33, 128]), seed=st.integers(0, 1000))
def test_ref_updates_matches_numpy_mirror(n, c, m, seed):
    rng = np.random.default_rng(seed)
    c = min(c, n)
    state, _ = _random_state(rng, n, c, 4)
    # include padded (>= n) and negative entries: excluded everywhere
    ids = np.where(rng.random(m) < 0.15, n, rng.integers(-1, n, m))
    sh_d, nm_d = cache_ref_updates(state.pos, jnp.asarray(ids, jnp.int32), c)
    sh_np, nm_np = featcache.cache_ref_updates_np(np.asarray(state.pos),
                                                  ids, c)
    np.testing.assert_array_equal(np.asarray(sh_d), sh_np)
    np.testing.assert_array_equal(np.asarray(nm_d), nm_np)
    # the vectors sum to the scalar counters (ONE counting rule)
    h, ms = featcache.cache_stats(state.pos, jnp.asarray(ids, jnp.int32), n)
    assert int(sh_d.sum()) == int(h) and int(nm_d.sum()) == int(ms)
    # and ref_updates/with_refs fold them identically to the np mirror
    st2 = dynamic.with_refs(state, dynamic.ref_updates(
        state, jnp.asarray(ids, jnp.int32)))
    snp = dynamic.ref_updates_np(dynamic.state_to_np(state), ids)
    assert _np_states_equal(dynamic.state_to_np(st2), snp)


# ---------------------------------------------------------------------------
# refill: jitted device path == pure-numpy CLOCK oracle, slot for slot
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([12, 40, 90]), c=st.sampled_from([1, 2, 5, 16]),
       max_freq=st.sampled_from([1, 2, 5]), seed=st.integers(0, 10_000))
def test_refill_matches_numpy_oracle(n, c, max_freq, seed):
    """Exact slot-for-slot equivalence on states dense with frequency
    ties: residency, rows, reference bits (including the ones a
    victimless walk leaves cleared), accumulator resets, hand position,
    and the admitted count."""
    rng = np.random.default_rng(seed)
    c = min(c, n)
    state, feats = _random_state(rng, n, c, 4, max_freq=max_freq)
    before = dynamic.state_to_np(state)
    st2, adm = dynamic.refill(state, jnp.asarray(feats))
    oracle, adm_np = dynamic.refill_np(before, feats)
    assert _np_states_equal(dynamic.state_to_np(st2), oracle)
    assert int(adm) == adm_np
    # epoch accumulators reset; the input state was not mutated
    assert int(st2.freq.sum()) == 0 and int(st2.slot_freq.sum()) == 0
    assert _np_states_equal(dynamic.state_to_np(state), before)


def test_pallas_counter_pipeline_matches_numpy():
    """The full device loop the trainer runs — gather_cached (Pallas
    path; interpret mode on CPU/CI) -> ref_updates -> refill — against
    the all-numpy mirror pipeline over the same batches."""
    rng = np.random.default_rng(5)
    n, c, f = 60, 13, 32
    state, feats = _random_state(rng, n, c, f, max_freq=1)
    # zero the randomized accumulators: the pipeline starts an epoch
    state = dynamic.with_refs(state, (jnp.zeros_like(state.refbit),
                                      jnp.zeros_like(state.slot_freq),
                                      jnp.zeros_like(state.freq)))
    snp = dynamic.state_to_np(state)
    for _ in range(4):
        ids = np.where(rng.random(25) < 0.1, n, rng.integers(0, n, 25))
        out, h, m = gather_cached(state.cache, jnp.asarray(feats),
                                  state.pos, jnp.asarray(ids, jnp.int32),
                                  impl="pallas")
        # served rows are exact copies wherever they live
        np.testing.assert_array_equal(
            np.asarray(out), feats[np.clip(ids, 0, n - 1)])
        state = dynamic.with_refs(state, dynamic.ref_updates(
            state, jnp.asarray(ids, jnp.int32)))
        snp = dynamic.ref_updates_np(snp, ids)
    state, adm = dynamic.refill(state, jnp.asarray(feats))
    snp, adm_np = dynamic.refill_np(snp, feats)
    assert _np_states_equal(dynamic.state_to_np(state), snp)
    assert int(adm) == adm_np


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([30, 80]), c=st.sampled_from([3, 9, 20]),
       seed=st.integers(0, 10_000))
def test_refill_preserves_residency_invariants(n, c, seed):
    """After any refill: pos/slot_ids stay a bijection, every cache row
    is an EXACT copy of its node's feature row (the bit-exactness the
    loss-trajectory guarantee rides on), and admitted rows come from the
    missed non-resident candidates."""
    rng = np.random.default_rng(seed)
    c = min(c, n)
    state, feats = _random_state(rng, n, c, 8)
    missed = set(np.where((np.asarray(state.pos) < 0)
                          & (np.asarray(state.freq) > 0))[0])
    resident_before = set(int(i) for i in np.asarray(state.slot_ids))
    st2, adm = dynamic.refill(state, jnp.asarray(feats))
    pos, sid = np.asarray(st2.pos), np.asarray(st2.slot_ids)
    assert len(set(sid)) == c                       # all distinct, none empty
    for s, node in enumerate(sid):
        assert node >= 0 and pos[node] == s
    assert np.count_nonzero(pos >= 0) == c
    np.testing.assert_array_equal(np.asarray(st2.cache), feats[sid])
    newcomers = set(int(i) for i in sid) - resident_before
    assert len(newcomers) == int(adm)
    assert newcomers <= missed


def test_refill_tie_breaking():
    """`CLOCK_TIE_BREAK` on the refill side, pinned slot-for-slot."""
    def state(pos_ids, n, refbit, slot_freq, freq, hand):
        c = len(pos_ids)
        feats = np.arange(n, dtype=np.float32).reshape(n, 1).repeat(2, 1)
        pos = np.full(n, -1, np.int32)
        pos[np.asarray(pos_ids)] = np.arange(c, dtype=np.int32)
        return DynamicCacheState(
            cache=jnp.asarray(feats[np.asarray(pos_ids)]),
            pos=jnp.asarray(pos),
            slot_ids=jnp.asarray(np.asarray(pos_ids, np.int32)),
            refbit=jnp.asarray(np.asarray(refbit, np.int32)),
            slot_freq=jnp.asarray(np.asarray(slot_freq, np.int32)),
            freq=jnp.asarray(np.asarray(freq, np.int32)),
            hand=jnp.asarray(hand, jnp.int32),
            capacity=c, policy="t"), jnp.asarray(feats)

    # rule 4: equal-frequency candidates admitted in ascending node id —
    # nodes 5,6,7 all have freq 2, two cold slots: 5 and 6 get them
    st, feats = state([0, 1, 2], 8, [0, 0, 0], [9, 0, 0],
                      [0, 0, 0, 0, 0, 2, 2, 2], hand=1)
    st2, adm = dynamic.refill(st, feats)
    assert int(adm) == 2
    assert list(np.asarray(st2.slot_ids)) == [0, 5, 6]
    # rule 5: candidate at EQUAL frequency to every occupant -> incumbent
    # stays (strictly-greater gate), nothing admitted
    st, feats = state([0, 1, 2], 6, [0, 0, 0], [2, 2, 2],
                      [0, 0, 0, 2, 2, 2], hand=0)
    st2, adm = dynamic.refill(st, feats)
    assert int(adm) == 0
    assert list(np.asarray(st2.slot_ids)) == [0, 1, 2]
    # rule 1: all slots clear and equally cold -> victim is the slot AT
    # the hand, hand advances one past it
    st, feats = state([0, 1, 2], 6, [0, 0, 0], [0, 0, 0],
                      [0, 0, 0, 5, 0, 0], hand=2)
    st2, adm = dynamic.refill(st, feats)
    assert int(adm) == 1
    assert list(np.asarray(st2.slot_ids)) == [0, 1, 3]
    assert int(st2.hand) == 0
    # rule 1 + second chance: referenced slot at the hand survives with
    # its bit stripped; the NEXT clear slot is the victim
    st, feats = state([0, 1, 2], 6, [0, 1, 0], [0, 9, 0],
                      [0, 0, 0, 5, 0, 0], hand=1)
    st2, adm = dynamic.refill(st, feats)
    assert int(adm) == 1
    assert list(np.asarray(st2.slot_ids)) == [0, 1, 3]
    assert not np.asarray(st2.refbit).any()
    assert int(st2.hand) == 0


# ---------------------------------------------------------------------------
# plan/state normalization
# ---------------------------------------------------------------------------
def test_as_cache_and_to_dynamic(tiny_graph):
    g = tiny_graph
    pol = make_policy("comm_rand", mix=0.0, p=1.0)
    kw = dict(policy=pol, batch_size=128, fanouts=(4, 4), seed=0,
              capacity=200)
    assert featcache.as_cache(None, g, **kw) is None
    plan = featcache.build_plan(g, "degree_hot", capacity=200)
    assert featcache.as_cache(plan, g, **kw) is plan
    stat = featcache.as_cache("degree_hot", g, **kw)
    assert isinstance(stat, featcache.CachePlan)
    dyn = featcache.as_cache("dynamic:degree_hot", g, **kw)
    assert isinstance(dyn, DynamicCacheState)
    assert featcache.as_cache(dyn, g, **kw) is dyn
    # to_dynamic: same residency, idle CLOCK machinery
    d2 = plan.to_dynamic()
    np.testing.assert_array_equal(np.asarray(d2.pos), np.asarray(plan.pos))
    np.testing.assert_array_equal(d2.cached_ids(), plan.cached_ids())
    np.testing.assert_array_equal(np.asarray(d2.cache),
                                  np.asarray(plan.cache))
    assert int(d2.hand) == 0 and int(d2.refbit.sum()) == 0
    assert "clock[degree_hot]" in d2.describe()
    # default dynamic seed admission is presampled_freq
    dyn2 = featcache.as_cache("dynamic", g, **kw)
    assert "presampled_freq" in dyn2.policy


# ---------------------------------------------------------------------------
# trainer integration
# ---------------------------------------------------------------------------
def _trainers(g, cal, cache, policy="comm_rand", seed=0, **kw):
    cfg = GNNConfig("t", "sage", 2, 32, g.feat_dim, g.num_classes,
                    fanout=(4, 4), dropout=0.5)
    tcfg = TrainConfig(batch_size=64, max_epochs=3)
    return GNNTrainer(g, cfg, tcfg, policy, seed=seed, calibrator=cal,
                      cache=cache, **kw)


def test_trainer_dynamic_cache_bit_identical(tiny_graph):
    """The 20-step loss-trajectory bit-match, WITH the cache evolving:
    train_steps crosses the epoch boundary, so a refill lands inside the
    window — rows are exact copies, so residency never touches the
    loss."""
    g = tiny_graph
    cal = CapsCalibrator(seed=0)
    t0 = _trainers(g, cal, None)
    t1 = _trainers(g, cal, "dynamic:degree_hot", cache_frac=0.3)
    assert isinstance(t1.cache, DynamicCacheState)
    assert t1.stream.cache is t1.cache
    nb = t1.stream.num_batches(0)
    l0, l1 = t0.train_steps(nb + 5), t1.train_steps(nb + 5)
    assert l0 == l1                       # bit-identical trajectory
    assert t1.cache_meter.refills > 0     # ...while the cache churned
    assert t1.stream.cache is t1.cache    # plumbing follows the state
    assert t0.cache_meter.total == 0 and t1.cache_meter.total > 0


def test_trainer_dynamic_cache_adapts(tiny_graph):
    """run_epoch fires exactly one refill per epoch boundary; residency
    stays consistent; the meter reports the per-epoch hit-rate/churn
    trajectory; accumulators are reset for the next epoch."""
    g = tiny_graph
    cal = CapsCalibrator(seed=0)
    t = _trainers(g, cal, "dynamic:degree_hot", cache_frac=0.3)
    seeded = np.asarray(t.cache.slot_ids).copy()
    ems = [t.run_epoch(1e-3) for _ in range(2)]
    traj = t.cache_meter.trajectory
    assert len(traj) == 2
    assert [e["cache_refill"] for e in ems] == \
        [x["refills"] for x in traj]
    assert t.cache_meter.refills == sum(x["refills"] for x in traj)
    assert traj[0]["refills"] > 0         # degree_hot seed must churn
    assert not np.array_equal(np.asarray(t.cache.slot_ids), seeded)
    assert 0.0 < ems[1]["cache_hit"] < 1.0
    # post-refill: fresh accumulators, consistent residency, exact rows
    assert int(t.cache.freq.sum()) == 0
    assert int(t.cache.slot_freq.sum()) == 0
    pos, sid = np.asarray(t.cache.pos), np.asarray(t.cache.slot_ids)
    assert all(pos[sid[s]] == s for s in range(len(sid)))
    np.testing.assert_array_equal(np.asarray(t.cache.cache),
                                  g.features[sid].astype(np.float32))


def test_eval_does_not_feed_admission(tiny_graph):
    """Evaluation reads through the cache but must not move the CLOCK:
    only the TRAINING distribution drives admission."""
    g = tiny_graph
    cal = CapsCalibrator(seed=0)
    t = _trainers(g, cal, "dynamic:degree_hot", cache_frac=0.3)
    t.train_steps(3)
    before = dynamic.state_to_np(t.cache)
    ev = t.evaluate(g.val_ids)
    assert 0.0 <= ev["acc"] <= 1.0
    assert _np_states_equal(dynamic.state_to_np(t.cache), before)


def test_dynamic_not_worse_than_static_replay(tiny_graph):
    """The fig10 acceptance inequality at test scale: on a replayed
    stream, the adapted CLOCK cache misses at most as many rows per batch
    as the static plan it was seeded from (the refill only swaps in rows
    that out-accessed their victims)."""
    g = tiny_graph
    pol = make_policy("comm_rand", mix=0.0, p=1.0)
    stream = featcache.policy_access_stream(g, pol, 128, (4, 4),
                                            n_batches=4, seed=7)
    for cap in (100, 400, 800):
        plan = featcache.build_plan(g, "presampled_freq", capacity=cap,
                                    policy=pol, batch_size=128,
                                    fanouts=(4, 4), seed=9)
        static = sum(featcache.cache_stats_np(
            np.asarray(plan.pos), ids, g.num_nodes)[1] for ids in stream)
        state = plan.to_dynamic()
        for e in range(2):
            miss = 0
            for ids in stream:
                d = jnp.asarray(ids, jnp.int32)
                miss += int(featcache.cache_stats(state.pos, d,
                                                  g.num_nodes)[1])
                state = dynamic.with_refs(
                    state, dynamic.ref_updates(state, d))
            if e == 0:
                assert miss == static     # pass 1 IS the static plan
                state, _ = dynamic.refill(state, jnp.asarray(g.features))
        assert miss <= static, (cap, miss, static)


# ---------------------------------------------------------------------------
# end-to-end resume: dynamic cache + comm_rand roots + LABOR sampler
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class _CommRandLabor(CommRandPolicy):
    """comm_rand root ordering trained through the LABOR shared-randomness
    sampler — the cross-product the resume contract must cover (epoch-key
    sampling state AND evolving cache state both derive from the
    cursor/checkpoint)."""

    def sampler_spec(self):
        return ("labor", {})

    def describe(self):
        return super().describe() + "+labor"


def test_resume_dynamic_cache_bit_exact(tiny_graph):
    """GNNTrainer with dynamic cache + comm_rand policy + labor sampler,
    checkpointed mid-training (one step past an epoch-boundary refill),
    resumes with a bit-identical loss trajectory and a bit-identical
    `DynamicCacheState` vs the uninterrupted run."""
    g = tiny_graph
    pol = _CommRandLabor("comm_rand", 0.0, 1.0)
    cal = CapsCalibrator(seed=0)

    def mk(d, every=0):
        return _trainers(g, cal, "dynamic:degree_hot", policy=pol,
                         cache_frac=0.3, ckpt_dir=d, ckpt_every=every)

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        a = mk(d1)
        assert a.sampler.describe().startswith("labor")
        nb = a.stream.num_batches(0)
        la = a.train_steps(nb + 5)        # straight through the refill
        b = mk(d2, every=1)
        b.train_steps(nb + 2)             # "crash" 2 steps past the refill
        del b
        b2 = mk(d2, every=1)
        assert b2.global_step == nb + 2   # resumed, mid-epoch cursor
        assert b2.stream.cursor.state() == {"epoch": 1, "pos": 2}
        lb = b2.train_steps(3)
        assert la[nb + 2:] == lb          # bit-identical continuation
        assert _np_states_equal(dynamic.state_to_np(a.cache),
                                dynamic.state_to_np(b2.cache))
        assert a.cache.capacity == b2.cache.capacity
        assert a.cache.policy == b2.cache.policy


def test_fit_reports_dynamic_cache_metrics(tiny_graph):
    """fit() surfaces the trajectory: per-epoch hit rate + refill churn
    in EpochMetrics, run totals in TrainResult."""
    g = tiny_graph
    cal = CapsCalibrator(seed=0)
    t = _trainers(g, cal, "dynamic:degree_hot", cache_frac=0.3)
    res = t.fit()
    assert res.cache.startswith("clock[degree_hot]")
    assert 0.0 < res.cache_hit_rate < 1.0
    assert res.cache_refills == t.cache_meter.refills > 0
    assert [h.cache_refills for h in res.history] == \
        [x["refills"] for x in t.cache_meter.trajectory[:len(res.history)]]
