"""Device-resident feature cache (`repro.featcache`): admission plans,
the two-level `gather_cached` kernel, the vectorized LRU simulator, and
the trainer's measured hit rates."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import featcache
from repro.featcache.sim import _lru_miss_rate_ref
from repro.kernels.gather_cached.ops import (cache_stats, gather_cached,
                                             resolve_cache_impl)
from repro.kernels.gather_cached.ref import gather_cached_ref


def _random_plan(rng, N, F, C):
    feats = jnp.asarray(rng.normal(size=(N, F)), jnp.float32)
    ids = np.sort(rng.choice(N, size=C, replace=False))
    pos = np.full(N, -1, np.int32)
    pos[ids] = np.arange(C, dtype=np.int32)
    return feats, feats[jnp.asarray(ids)], jnp.asarray(pos), ids


# ---------------------------------------------------------------------------
# gather_cached: jnp <-> pallas fwd/bwd equivalence
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([16, 50, 200]), c=st.sampled_from([1, 7, 40]),
       m=st.sampled_from([4, 33, 128]), f=st.sampled_from([8, 64, 128]),
       seed=st.integers(0, 20))
def test_gather_cached_matches_ref(n, c, m, f, seed):
    rng = np.random.default_rng(seed)
    c = min(c, n)
    feats, cache, pos, _ = _random_plan(rng, n, f, c)
    # include padded (>= n) entries: served from a clipped row, not counted
    ids = jnp.asarray(np.where(rng.random(m) < 0.15, n,
                               rng.integers(0, n, m)), jnp.int32)
    out_j, h_j, m_j = gather_cached(cache, feats, pos, ids, impl="jnp")
    out_p, h_p, m_p = gather_cached(cache, feats, pos, ids, impl="pallas")
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_j))
    assert (int(h_p), int(m_p)) == (int(h_j), int(m_j))


@settings(max_examples=6, deadline=None)
@given(n=st.sampled_from([20, 80]), c=st.sampled_from([5, 30]),
       m=st.sampled_from([7, 40]), f=st.sampled_from([16, 64]),
       seed=st.integers(0, 20))
def test_gather_cached_grads_match_ref(n, c, m, f, seed):
    """Backward (two fanout-1 scatter-adds) vs autodiff of the jnp ref."""
    rng = np.random.default_rng(seed)
    c = min(c, n)
    feats, cache, pos, _ = _random_plan(rng, n, f, c)
    ids = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    cot = jnp.asarray(rng.normal(size=(m, f)), jnp.float32)

    def grads(impl):
        return jax.grad(
            lambda ca, fe: (gather_cached(ca, fe, pos, ids,
                                          impl=impl)[0] * cot).sum(),
            argnums=(0, 1))(cache, feats)

    (dcp, dfp), (dcj, dfj) = grads("pallas"), grads("jnp")
    np.testing.assert_allclose(np.asarray(dcp), np.asarray(dcj),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dfp), np.asarray(dfj),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("edge", ["all_hit", "all_miss"])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_gather_cached_hit_miss_edges(edge, impl):
    rng = np.random.default_rng(3)
    N, F, M = 24, 32, 17
    feats = jnp.asarray(rng.normal(size=(N, F)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, N, M), jnp.int32)
    if edge == "all_hit":
        cache, pos = feats, jnp.arange(N, dtype=jnp.int32)
    else:
        cache, pos = feats[:1], jnp.full((N,), -1, jnp.int32)
    cot = jnp.asarray(rng.normal(size=(M, F)), jnp.float32)
    out, h, m = gather_cached(cache, feats, pos, ids, impl=impl)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(feats)[ids])
    assert (int(h), int(m)) == ((M, 0) if edge == "all_hit" else (0, M))
    dc, df = jax.grad(
        lambda ca, fe: (gather_cached(ca, fe, pos, ids,
                                      impl=impl)[0] * cot).sum(),
        argnums=(0, 1))(cache, feats)
    tot = np.zeros((N, F), np.float32)
    np.add.at(tot, np.asarray(ids), np.asarray(cot))
    hot, cold = (dc, df) if edge == "all_hit" else (df, dc)
    np.testing.assert_allclose(np.asarray(hot), tot, rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(cold).max()) == 0.0


def test_resolve_cache_impl():
    assert resolve_cache_impl("jnp") == "jnp"
    assert resolve_cache_impl("pallas") == "pallas"
    assert resolve_cache_impl("auto") == "jnp"   # CPU suite
    with pytest.raises(ValueError):
        resolve_cache_impl("nope")


# ---------------------------------------------------------------------------
# admission plans: device counters bit-match the numpy mirror
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("admission", featcache.available_admissions())
def test_plan_counters_match_numpy_mirror(tiny_graph, admission):
    from repro.batching import make_policy
    g = tiny_graph
    pol = make_policy("comm_rand", mix=0.0, p=1.0)
    plan = featcache.build_plan(g, admission, capacity=300, policy=pol,
                                batch_size=128, fanouts=(4, 4), seed=0)
    assert plan.capacity == 300
    ids = plan.cached_ids()
    assert len(ids) == 300 and len(np.unique(ids)) == 300
    # cache rows are exact copies of the admitted feature rows
    np.testing.assert_array_equal(np.asarray(plan.cache),
                                  g.features[ids].astype(np.float32))
    stream = featcache.policy_access_stream(g, pol, 128, (4, 4),
                                            n_batches=4, seed=7)
    for batch_ids in stream:
        dev = cache_stats(plan.pos, jnp.asarray(batch_ids, jnp.int32),
                          g.num_nodes)
        np_hits, np_misses = featcache.cache_stats_np(
            np.asarray(plan.pos), batch_ids, g.num_nodes)
        assert (int(dev[0]), int(dev[1])) == (np_hits, np_misses)
        # and gather_cached's own counters are the same numbers
        _, h2, m2 = gather_cached(plan.cache, jnp.asarray(g.features),
                                  plan.pos, jnp.asarray(batch_ids,
                                                        jnp.int32))
        assert (int(h2), int(m2)) == (np_hits, np_misses)


def test_admission_policies_rank_differently(tiny_graph):
    """degree_hot ignores structure; community_freq must not (the tiny
    graph has communities of very different training mass)."""
    g = tiny_graph
    deg = featcache.make_admission("degree_hot").scores(g, {})
    com = featcache.make_admission("community_freq").scores(g, {})
    assert not np.array_equal(featcache.select_rows(deg, 200),
                              featcache.select_rows(com, 200))


# ---------------------------------------------------------------------------
# apply_gnn: cache on == cache off, for every model, both impls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model", ["sage", "gcn", "gat"])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_apply_gnn_cache_is_pure_read_path(tiny_graph, model, impl):
    from repro.configs.base import GNNConfig
    from repro.core import minibatch as mb
    from repro.graphs.csr import DeviceGraph
    from repro.models.gnn.models import apply_gnn, init_gnn

    g = tiny_graph
    gdev = DeviceGraph.from_graph(g)
    feats = jnp.asarray(g.features)
    cfg = GNNConfig("t", model, 2, 32, g.feat_dim, g.num_classes,
                    fanout=(4, 4), dropout=0.0, agg_impl=impl)
    params = init_gnn(cfg, jax.random.key(1))
    batch = mb.build_batch(jax.random.key(2), gdev,
                           jnp.asarray(g.train_ids[:32], jnp.int32),
                           jnp.asarray(g.labels), (4, 4), (256, 384), 0.9)
    plan = featcache.build_plan(g, "degree_hot", capacity=500)
    out = apply_gnn(cfg, params, batch, feats, gdev.degrees,
                    feats_global=True)
    out_c = apply_gnn(cfg, params, batch, feats, gdev.degrees,
                      feats_global=True, cache=plan)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out))


def test_apply_gnn_cache_requires_feats_global(tiny_graph):
    from repro.configs.base import GNNConfig
    from repro.core import minibatch as mb
    from repro.graphs.csr import DeviceGraph
    from repro.models.gnn.models import apply_gnn, init_gnn

    g = tiny_graph
    gdev = DeviceGraph.from_graph(g)
    cfg = GNNConfig("t", "sage", 2, 32, g.feat_dim, g.num_classes,
                    fanout=(4, 4), dropout=0.0, agg_impl="jnp")
    params = init_gnn(cfg, jax.random.key(1))
    batch = mb.build_batch(jax.random.key(2), gdev,
                           jnp.asarray(g.train_ids[:8], jnp.int32),
                           jnp.asarray(g.labels), (4, 4), (256, 384), 0.9)
    plan = featcache.build_plan(g, "degree_hot", capacity=100)
    with pytest.raises(ValueError, match="feats_global"):
        apply_gnn(cfg, params, batch,
                  jnp.asarray(g.features)[batch.node_ids], gdev.degrees,
                  cache=plan)


# ---------------------------------------------------------------------------
# trainer: cache is a pure read-path optimization with measured hit rates
# ---------------------------------------------------------------------------
def test_trainer_cache_bit_identical_with_hit_rates(tiny_graph):
    from repro.batching import CapsCalibrator
    from repro.configs.base import GNNConfig, TrainConfig
    from repro.train.gnn_loop import GNNTrainer

    g = tiny_graph
    cfg = GNNConfig("t", "sage", 2, 32, g.feat_dim, g.num_classes,
                    fanout=(4, 4), dropout=0.5)
    tcfg = TrainConfig(batch_size=64, max_epochs=2)
    cal = CapsCalibrator(seed=0)
    t0 = GNNTrainer(g, cfg, tcfg, "comm_rand", seed=0, calibrator=cal)
    t1 = GNNTrainer(g, cfg, tcfg, "comm_rand", seed=0, calibrator=cal,
                    cache="presampled_freq", cache_frac=0.3)
    assert t0.cache is None and t1.cache is not None
    assert t1.stream.cache is t1.cache        # plumbing rides the stream
    l0, l1 = t0.train_steps(20), t1.train_steps(20)
    assert l0 == l1                           # bit-identical trajectory
    assert t1.cache_meter.total > 0
    assert 0.0 < t1.cache_meter.hit_rate < 1.0
    assert t0.cache_meter.total == 0
    # the meter's accumulated device counters bit-match the numpy mirror
    # replayed over an identical stream (same seed/policy/caps -> same
    # compiled batches)
    from repro.batching import BatchStream
    replay = BatchStream(g, t1.policy, tcfg.batch_size, t1.fanouts,
                         t1.caps, seed=0, device_graph=t1.g,
                         labels=t1.labels)
    it = iter(replay)
    exp_h = exp_m = 0
    for _ in range(20):
        bh, bm = featcache.cache_stats_np(
            np.asarray(t1.cache.pos), np.asarray(next(it).node_ids),
            g.num_nodes)
        exp_h += bh
        exp_m += bm
    assert (t1.cache_meter.hits, t1.cache_meter.misses) == (exp_h, exp_m)
    em = t1.run_epoch(1e-3)                   # per-epoch rate in metrics
    assert 0.0 <= em["cache_hit"] <= 1.0


# ---------------------------------------------------------------------------
# simulator: property-based invariants on generated access streams
# ---------------------------------------------------------------------------
def _stream(seed):
    """A random batch-deduped access stream (the upstream contract:
    per-batch arrays of unique node ids)."""
    rng = np.random.default_rng(seed)
    universe = int(rng.integers(5, 60))
    return [rng.choice(universe, size=rng.integers(1, min(universe, 30) + 1),
                       replace=False)
            for _ in range(rng.integers(1, 8))]


def _compulsory_floor(batches):
    """#distinct / #accesses: no demand-fetch cache misses less."""
    total = sum(len(b) for b in batches)
    return len(np.unique(np.concatenate(batches))) / max(total, 1)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 100_000), cap=st.integers(1, 64))
def test_sim_invariants_on_generated_streams(seed, cap):
    """The simulator invariants, on generated streams:
    (1) vectorized LRU is EXACTLY the OrderedDict loop (the spot-check of
        test_lru_vectorized_matches_loop, promoted to the generator);
    (2) LRU and CLOCK both pay at least the compulsory-miss floor;
    (3) LRU is a stack algorithm: monotone non-increasing in capacity;
    (4) at capacity >= #distinct ids both collapse to exactly the floor."""
    batches = _stream(seed)
    floor = _compulsory_floor(batches)
    n_distinct = len(np.unique(np.concatenate(batches)))
    lru = featcache.lru_miss_rate(batches, cap)
    clock = featcache.clock_miss_rate(batches, cap)
    assert lru == _lru_miss_rate_ref(batches, cap)
    assert lru >= floor - 1e-12
    assert clock >= floor - 1e-12
    assert featcache.lru_miss_rate(batches, cap + 1) <= lru + 1e-12
    assert featcache.lru_miss_rate(batches, n_distinct) == \
        pytest.approx(floor, abs=1e-12)
    assert featcache.clock_miss_rate(batches, n_distinct) == \
        pytest.approx(floor, abs=1e-12)


def test_clock_tracks_lru_in_aggregate():
    """The relationship the issue's naive `clock >= lru` gestures at, in
    its SOUND form: CLOCK is a one-bit approximation of LRU, not a stack
    algorithm (see the pinned counterexample below), so it is neither
    pointwise above LRU nor monotone in capacity. What does hold — and
    what this pins over a fixed deterministic population — is that CLOCK
    TRACKS LRU: it misses at least as much on the overwhelming majority
    of (stream, capacity) pairs, and its mean miss rate sits within half
    a percentage point of LRU's."""
    draws = wins = 0
    clock_sum = lru_sum = 0.0
    for seed in range(120):
        batches = _stream(seed)
        for cap in (2, 5, 11, 23):
            c = featcache.clock_miss_rate(batches, cap)
            lr = featcache.lru_miss_rate(batches, cap)
            draws += 1
            wins += c >= lr - 1e-12
            clock_sum += c
            lru_sum += lr
    assert wins / draws >= 0.9
    assert abs(clock_sum - lru_sum) / draws <= 0.005


def test_clock_is_not_dominated_by_lru():
    """The boundary of the aggregate property, pinned: a stream where
    second-chance hand order outright beats LRU (and why the dynamic
    refill adds a frequency gate instead of trusting hand order alone)."""
    batches = [np.array(b) for b in
               ([2, 5], [1, 4], [4, 5], [1], [2, 3, 0, 4, 5],
                [1, 0, 5, 2, 4, 3])]
    assert featcache.clock_miss_rate(batches, 5) < \
        featcache.lru_miss_rate(batches, 5)


def test_clock_replay_pins_tie_breaking():
    """`CLOCK_TIE_BREAK` on the simulator side, slot for slot: fill
    order, victim-at-hand among all-clear slots, second chance, inserted
    bits start clear. The refill shares the rule (its side is pinned in
    tests/test_featcache_dynamic.py)."""
    # rule 2: empty slots fill in ascending slot order; the hand is idle
    _, slot_id, refbit, hand, filled = featcache.clock_replay(
        [np.array([7, 3, 9])], 3)
    assert list(slot_id) == [7, 3, 9] and hand == 0 and filled == 3
    assert not refbit.any()                # rule 3: inserts start CLEAR
    # rule 1 at an all-clear tie: the victim is the slot AT the hand
    _, slot_id, refbit, hand, _ = featcache.clock_replay(
        [np.array([7, 3, 9]), np.array([5])], 3)
    assert list(slot_id) == [5, 3, 9] and hand == 1
    # second chance: referenced slot 0 survives (bit stripped in passing),
    # the next clear slot from the hand (slot 1) is evicted
    _, slot_id, refbit, hand, _ = featcache.clock_replay(
        [np.array([7, 3, 9]), np.array([7]), np.array([5])], 3)
    assert list(slot_id) == [7, 5, 9] and hand == 2
    assert not refbit.any()


# ---------------------------------------------------------------------------
# simulator: vectorized LRU == OrderedDict loop, CLOCK sanity
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), cap=st.integers(1, 64),
       dedup=st.booleans())
def test_lru_vectorized_matches_loop(seed, cap, dedup):
    rng = np.random.default_rng(seed)
    if dedup:   # the contract: per-batch arrays of already-deduped ids
        batches = [rng.choice(60, size=rng.integers(0, 40), replace=False)
                   for _ in range(rng.integers(1, 6))]
    else:       # robustness: intra-batch duplicates must still match
        batches = [rng.integers(0, 25, size=rng.integers(0, 50))
                   for _ in range(rng.integers(1, 5))]
    assert featcache.lru_miss_rate(batches, cap) == \
        _lru_miss_rate_ref(batches, cap)


def test_lru_empty_stream():
    assert featcache.lru_miss_rate([], 4) == 1.0
    assert featcache.lru_miss_rate([np.array([], np.int64)], 4) == 1.0


def test_clock_approximates_lru():
    """Sequential sweeps: CLOCK and LRU agree exactly (no reuse to
    second-chance); a hot-id stream hits under both."""
    sweeps = [np.arange(16) for _ in range(3)]
    assert featcache.clock_miss_rate(sweeps, 8) == \
        featcache.lru_miss_rate(sweeps, 8) == 1.0
    hot = [np.array([1, 2, 3])] * 8
    assert featcache.clock_miss_rate(hot, 4) == \
        featcache.lru_miss_rate(hot, 4) == pytest.approx(3 / 24)


def test_static_miss_rate():
    batches = [np.array([0, 1, 2, 3]), np.array([2, 3, 4, 5])]
    assert featcache.static_miss_rate(batches, np.array([2, 3])) == 0.5


# ---------------------------------------------------------------------------
# deprecated shim
# ---------------------------------------------------------------------------
def test_core_cachesim_shim_warns_and_delegates():
    from repro.core import cachesim
    batches = [np.array([1, 2, 3]), np.array([2, 3, 4])]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = cachesim.lru_miss_rate(batches, 8)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert out == featcache.lru_miss_rate(batches, 8)
