"""`repro.pipeline` contract tests: device order mirror, fused builder,
async prefetch stream, cursor resume, and the legacy-flag deprecation."""
import tempfile
import warnings

import jax
import numpy as np
import pytest

from repro.batching import BatchStream, Cursor, make_policy
from repro.batching.policy import CommRandPolicy
from repro.pipeline import (AsyncBatchStream, DeviceBatchBuilder,
                            order_bitmatch)
from repro.pipeline.builder import stage_times
from repro.pipeline.device_order import OrderSpec, device_epoch_order, \
    epoch_words_for
from repro.sampling.device import LaborSampler

BATCH = 128
FANOUTS = (5, 5)
CAPS = (512, 1024)


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# device order mirror
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,kw", [
    ("rand", {}), ("norand", {}), ("comm_rand", {"mix": 0.0}),
    ("comm_rand", {"mix": 0.125}), ("comm_rand", {"mix": 1.0}),
    ("clustergcn", {}), ("labor", {}),
])
def test_device_order_bitmatches_numpy(tiny_graph, name, kw):
    """The jitted epoch order equals the numpy policy path element for
    element, across epochs — the contract that lets the fused builder
    skip the host entirely."""
    pol = make_policy(name, **kw)
    assert order_bitmatch(tiny_graph, pol, seed=3, epochs=(0, 1, 2))


def test_device_order_is_permutation_and_varies(tiny_graph):
    spec = OrderSpec.for_policy(tiny_graph, make_policy("comm_rand"))
    o0 = np.asarray(device_epoch_order(spec, epoch_words_for(0, 0)))
    o1 = np.asarray(device_epoch_order(spec, epoch_words_for(0, 1)))
    ref = np.sort(np.asarray(tiny_graph.train_ids))
    assert np.array_equal(np.sort(o0), ref)
    assert np.array_equal(np.sort(o1), ref)
    assert not np.array_equal(o0, o1)        # epochs reshuffle


def test_unknown_policy_raises():
    class Odd:
        name = "odd"
        p = 0.5

    with pytest.raises(NotImplementedError):
        OrderSpec.for_policy(None, Odd())


# ---------------------------------------------------------------------------
# fused builder vs synchronous stream
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pname", ["comm_rand", "labor", "clustergcn"])
def test_fused_build_bitexact_vs_stream(tiny_graph, pname):
    """`DeviceBatchBuilder.build(epoch, pos)` returns the same MiniBatch —
    every leaf bit-equal — as `BatchStream.build` at the same cursor,
    including the -1-padded final batch."""
    st = BatchStream(tiny_graph, make_policy(pname), BATCH, FANOUTS, CAPS,
                     seed=7)
    bld = DeviceBatchBuilder.from_stream(st)
    last = bld.num_batches - 1
    for epoch, pos in [(0, 0), (0, 2), (1, last), (3, 1)]:
        want = st.build(st.root_batches(epoch)[pos], epoch, pos)
        got = bld.build(epoch, pos)
        assert _leaves_equal(want, got), (epoch, pos)


def test_builder_rejects_out_of_range(tiny_graph):
    bld = DeviceBatchBuilder(tiny_graph, make_policy("rand"), BATCH,
                             FANOUTS, CAPS)
    with pytest.raises(IndexError):
        bld.build(0, bld.num_batches)


def test_labor_rank_hoist_matches_numpy_mirror(tiny_graph):
    """The per-epoch device ranks (`epoch_ctx`) and the numpy mirror
    (`epoch_ranks_np`) are bit-identical — the invariant that keeps
    `build_batch_np` a valid oracle after the hoist."""
    from repro.graphs.csr import DeviceGraph
    s = LaborSampler()
    g = DeviceGraph.from_graph(tiny_graph)
    for epoch in (0, 5):
        key = jax.random.fold_in(jax.random.key(7), epoch)
        dev = np.asarray(s.epoch_ctx(key, g))
        host = s.epoch_ranks_np(key, tiny_graph.num_nodes)
        assert np.array_equal(dev.view(np.uint32), host.view(np.uint32))


def test_stage_times_shape(tiny_graph):
    st = BatchStream(tiny_graph, make_policy("comm_rand"), BATCH, FANOUTS,
                     CAPS)
    bd = stage_times(st.g, st.root_batches(0)[0], st.labels, FANOUTS, CAPS,
                     st.sampler, key=st.batch_key(0, 0),
                     epoch_key=st.epoch_key(0), iters=2)
    assert set(bd) == {"roots_us", "sample_us", "dedup_us"}
    assert all(v > 0 for v in bd.values())


# ---------------------------------------------------------------------------
# async stream: sequence + resume
# ---------------------------------------------------------------------------
def test_async_sequence_bitexact_vs_sync(tiny_graph):
    """Batches delivered by the background prefetcher are bit-equal to
    the synchronous stream's, in order, across an epoch boundary, and
    both cursors stay in lockstep."""
    pol = make_policy("comm_rand")
    sync = BatchStream(tiny_graph, pol, BATCH, FANOUTS, CAPS, seed=7)
    asyn = AsyncBatchStream(tiny_graph, pol, BATCH, FANOUTS, CAPS, seed=7)
    try:
        nb = sync.num_batches(0)
        it_s, it_a = iter(sync), iter(asyn)
        for _ in range(nb + 3):                 # crosses into epoch 1
            assert _leaves_equal(next(it_s), next(it_a))
            assert sync.cursor.state() == asyn.cursor.state()
    finally:
        asyn.close()


def test_async_resume_mid_epoch_bitexact(tiny_graph):
    """Kill the async stream mid-epoch with depth-2 builds in flight,
    restore a fresh stream from `Cursor.state()`: the continuation
    matches an uninterrupted synchronous run batch for batch."""
    pol = make_policy("comm_rand")
    sync = BatchStream(tiny_graph, pol, BATCH, FANOUTS, CAPS, seed=7)
    asyn = AsyncBatchStream(tiny_graph, pol, BATCH, FANOUTS, CAPS, seed=7,
                            depth=2)
    it_s, it_a = iter(sync), iter(asyn)
    for _ in range(4):                          # mid-epoch, queue full
        next(it_s)
        next(it_a)
    saved = asyn.cursor.state()
    asyn.close()                                # "crash" with work in flight

    resumed = AsyncBatchStream(tiny_graph, pol, BATCH, FANOUTS, CAPS,
                               seed=7, depth=2)
    resumed.cursor = Cursor.from_state(saved)
    try:
        it_r = iter(resumed)
        nb = sync.num_batches(0)
        for _ in range(nb):                     # through the epoch boundary
            assert _leaves_equal(next(it_s), next(it_r))
    finally:
        resumed.close()


def test_async_external_cursor_reset_realigns(tiny_graph):
    """Assigning a new Cursor to a LIVE async stream (the trainer's
    `_try_resume` path) discards in-flight work and realigns."""
    pol = make_policy("rand")
    sync = BatchStream(tiny_graph, pol, BATCH, FANOUTS, CAPS, seed=1)
    asyn = AsyncBatchStream(tiny_graph, pol, BATCH, FANOUTS, CAPS, seed=1)
    try:
        it_a = iter(asyn)
        for _ in range(3):
            next(it_a)
        asyn.cursor = Cursor(2, 5)              # jump while producer runs
        got = next(iter(asyn))
        want = sync.build(sync.root_batches(2)[5], 2, 5)
        assert _leaves_equal(want, got)
    finally:
        asyn.close()


# ---------------------------------------------------------------------------
# legacy flag deprecation
# ---------------------------------------------------------------------------
def test_prefetch_flag_deprecated_but_compatible(tiny_graph):
    """`BatchStream(prefetch=...)` warns (it never prefetched — single
    synchronous dispatch slot) and maps onto `dispatch_ahead`; the new
    name is silent."""
    with pytest.warns(DeprecationWarning, match="AsyncBatchStream"):
        st = BatchStream(tiny_graph, make_policy("rand"), BATCH, FANOUTS,
                         CAPS, prefetch=False)
    assert st.dispatch_ahead is False
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        st2 = BatchStream(tiny_graph, make_policy("rand"), BATCH, FANOUTS,
                          CAPS, dispatch_ahead=True)
    assert st2.dispatch_ahead is True


# ---------------------------------------------------------------------------
# 20-step loss trajectory: async + resume == sync (comm_rand x LABOR,
# cache on)
# ---------------------------------------------------------------------------
class CommRandLaborPolicy(CommRandPolicy):
    """comm_rand root ordering trained through the LABOR sampler — the
    satellite's cross product (structure-aware roots x shared-randomness
    neighbors)."""

    def sampler_spec(self):
        return ("labor", {})


def _trainer(tiny_graph, tmp=None, pipeline="sync", **kw):
    from repro.configs.base import GNNConfig, TrainConfig
    from repro.train.gnn_loop import GNNTrainer
    cfg = GNNConfig("sage-pipe", "sage", 2, 16, tiny_graph.feat_dim,
                    tiny_graph.num_classes, fanout=FANOUTS)
    tcfg = TrainConfig(batch_size=BATCH, max_epochs=2)
    return GNNTrainer(tiny_graph, cfg, tcfg,
                      CommRandLaborPolicy("comm_rand", 0.125, 1.0),
                      caps=CAPS, eval_caps=CAPS, seed=3,
                      cache="degree_hot", pipeline=pipeline, **kw)


def test_async_train_resume_loss_trajectory_bitexact(tiny_graph):
    """comm_rand roots x LABOR sampler, feature cache on: 20 sync steps
    vs 8 async steps + mid-epoch crash (depth-2 in flight) + resume from
    the checkpoint cursor + 12 more — identical loss trajectory, bit for
    bit, and identical batch key/cursor sequence."""
    ref = _trainer(tiny_graph, pipeline="sync")
    ref_losses = ref.train_steps(20)

    with tempfile.TemporaryDirectory() as d:
        a = _trainer(tiny_graph, tmp=d, pipeline="async", ckpt_dir=d,
                     ckpt_every=8)
        assert isinstance(a.stream, AsyncBatchStream)
        first = a.train_steps(8)                # ckpt fires at step 8
        cursor_at_kill = a.stream.cursor.state()
        a.stream.close()                        # crash with work in flight
        del a

        b = _trainer(tiny_graph, tmp=d, pipeline="async", ckpt_dir=d,
                     ckpt_every=0)
        try:
            assert b.global_step == 8
            assert b.stream.cursor.state() == cursor_at_kill
            rest = b.train_steps(12)
        finally:
            b.stream.close()

    got = first + rest
    assert got == ref_losses                    # bit-exact, not allclose
