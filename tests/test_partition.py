"""Biased root partitioning: permutation + structure properties
(paper §4.1 / Table 1)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import CommRandPolicy
from repro.core import partition


def _setup(n=500, n_comm=10, seed=0):
    rng = np.random.default_rng(seed)
    train_ids = np.sort(rng.choice(2000, n, replace=False))
    communities = rng.integers(0, n_comm, 2000).astype(np.int32)
    return train_ids, communities, rng


@settings(max_examples=15, deadline=None)
@given(mode=st.sampled_from(["rand", "norand", "comm_rand"]),
       mix=st.sampled_from([0.0, 0.125, 0.25, 0.5]),
       seed=st.integers(0, 100))
def test_epoch_order_is_permutation(mode, mix, seed):
    train_ids, communities, _ = _setup(seed=seed % 7)
    pol = CommRandPolicy(mode, mix, 1.0)
    rng = np.random.default_rng(seed)
    order = partition.epoch_order(train_ids, communities, pol, rng)
    assert np.array_equal(np.sort(order), np.sort(train_ids))


def test_norand_is_static_and_community_sorted():
    train_ids, communities, rng = _setup()
    pol = CommRandPolicy("norand")
    o1 = partition.epoch_order(train_ids, communities, pol, rng)
    o2 = partition.epoch_order(train_ids, communities, pol, rng)
    assert np.array_equal(o1, o2)
    comm_seq = communities[o1]
    assert np.sum(np.diff(comm_seq) != 0) == len(np.unique(comm_seq)) - 1


def test_rand_differs_across_epochs():
    train_ids, communities, rng = _setup()
    pol = CommRandPolicy("rand")
    o1 = partition.epoch_order(train_ids, communities, pol, rng)
    o2 = partition.epoch_order(train_ids, communities, pol, rng)
    assert not np.array_equal(o1, o2)


def test_comm_rand_mix0_keeps_community_blocks():
    """MIX-0%: each community stays contiguous, contents shuffled."""
    train_ids, communities, rng = _setup()
    pol = CommRandPolicy("comm_rand", 0.0, 1.0)
    o = partition.epoch_order(train_ids, communities, pol, rng)
    comm_seq = communities[o]
    assert np.sum(np.diff(comm_seq) != 0) == len(np.unique(comm_seq)) - 1
    o2 = partition.epoch_order(train_ids, communities, pol, rng)
    assert not np.array_equal(o, o2)   # randomized within blocks


def test_mixing_increases_batch_community_diversity():
    """Paper Fig 3: more mixing -> more communities per batch."""
    train_ids, communities, rng = _setup(n=1000, n_comm=20)
    div = {}
    for mix in (0.0, 0.25, 0.5):
        pol = CommRandPolicy("comm_rand", mix, 1.0)
        batches = partition.batches_for_epoch(train_ids, communities, pol,
                                              64, np.random.default_rng(1))
        div[mix] = partition.communities_per_batch(batches, communities)
    rand_batches = partition.batches_for_epoch(
        train_ids, communities, CommRandPolicy("rand"), 64,
        np.random.default_rng(1))
    div["rand"] = partition.communities_per_batch(rand_batches, communities)
    assert div[0.0] <= div[0.25] <= div[0.5] <= div["rand"] + 1e-9


def test_make_batches_pads_last():
    out = partition.make_batches(np.arange(10), 4)
    assert out.shape == (3, 4)
    assert (out[-1][2:] == -1).all()


def test_label_diversity_metric_decreases_with_bias(tiny_graph):
    """Paper Fig 7 direction: NORAND has fewer labels/batch than RAND."""
    g = tiny_graph
    rng = np.random.default_rng(0)
    b_rand = partition.batches_for_epoch(
        g.train_ids, g.communities, CommRandPolicy("rand"), 128, rng)
    b_nor = partition.batches_for_epoch(
        g.train_ids, g.communities, CommRandPolicy("norand"), 128, rng)
    assert partition.labels_per_batch(b_nor, g.labels) <= \
        partition.labels_per_batch(b_rand, g.labels)
