"""`repro.obs` suite: tracer round-trip + Perfetto conformance, analyzer
arithmetic on synthetic span sets, MetricsHub primitives + export schema,
meter-absorption equivalence on a real trainer run, and the zero-cost
contract (tracing on vs off is loss-bit-identical)."""
import json
import threading

import jax
import numpy as np
import pytest

from repro.obs import metrics as M
from repro.obs import report as R
from repro.obs import trace as T
from repro.obs.__main__ import main as obs_cli
from repro.resilience import soak
from repro.train.monitor import (HitRateMeter, ResilienceMeter,
                                 StragglerMonitor)


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """Every test starts and ends with tracing disabled."""
    T.uninstall()
    yield
    T.uninstall()


# ---------------------------------------------------------------------------
# tracer: JSONL round-trip + conformance
# ---------------------------------------------------------------------------
def test_trace_jsonl_roundtrip(tmp_path):
    p = str(tmp_path / "t.jsonl")
    with T.enabled(p, run="unit") as tr:
        with T.span("alpha", cat="step", step=3):
            pass
        with T.span("beta", cat="sync") as s:
            s.set(found=7)
        T.instant("tick", cat="host", k=1)
        tr.flush()
    evs = R.load_trace(p)
    assert [e["name"] for e in evs] == ["alpha", "beta", "tick"]
    a, b, i = evs
    assert a["cat"] == "step" and a["ph"] == "X" and a["dur"] >= 0
    assert a["args"]["step"] == 3
    assert b["args"]["found"] == 7          # set() attached mid-span
    assert i["ph"] == "i"
    # metadata header is skipped by default, present on request
    with_meta = R.load_trace(p, include_meta=True)
    assert with_meta[0]["ph"] == "M"
    assert with_meta[0]["args"]["schema_version"] == T.TRACE_SCHEMA_VERSION
    assert with_meta[0]["args"]["run"] == "unit"


def test_trace_conformance_and_chrome_wrapper(tmp_path):
    p = str(tmp_path / "t.jsonl")
    with T.enabled(p) as tr:
        for i in range(5):
            with T.span(f"s{i}", cat="step"):
                pass
        tr.flush()
    evs = R.load_trace(p)
    assert R.validate_events(evs) == []
    out = str(tmp_path / "chrome.json")
    R.to_chrome(evs, out)
    wrapped = json.loads(open(out).read())
    assert set(wrapped) == {"traceEvents", "displayTimeUnit"}
    assert len(wrapped["traceEvents"]) == 5
    for ev in wrapped["traceEvents"]:       # the Perfetto-required keys
        assert {"name", "cat", "ph", "ts", "pid", "tid",
                "dur"} <= set(ev)


def test_validate_events_flags_malformed():
    bad = [{"name": "x", "cat": "c", "ph": "X", "ts": 0.0, "pid": 1,
            "tid": 1},                       # X without dur
           {"cat": "c", "ph": "i", "ts": 0.0, "pid": 1, "tid": 1},  # no name
           {"name": "y", "cat": "c", "ph": "X", "ts": 0.0, "pid": 1,
            "tid": 1, "dur": -3.0}]          # negative dur
    problems = R.validate_events(bad)
    assert len(problems) == 3


def test_load_trace_raises_on_torn_line(tmp_path):
    p = tmp_path / "torn.jsonl"
    p.write_text('{"name": "ok", "cat": "c", "ph": "i", "ts": 0, '
                 '"pid": 1, "tid": 1}\n{"name": "torn", "ca\n')
    with pytest.raises(ValueError, match="bad trace line"):
        R.load_trace(str(p))


def test_disabled_tracing_is_noop_singleton():
    assert T.current() is None
    s = T.span("anything", cat="step", x=1)
    assert s is T.NOOP                      # no allocation when disabled
    with s as inner:
        inner.set(y=2)                      # chainable, does nothing
    T.instant("nothing")                    # no tracer: swallowed


def test_tracer_multithread_tids():
    with T.enabled(None) as tr:
        with T.span("main_work", cat="step"):
            pass

        def worker():
            with T.span("thread_work", cat="producer"):
                pass

        th = threading.Thread(target=worker)
        th.start()
        th.join()
        evs = tr.events()
    tids = {e["name"]: e["tid"] for e in evs}
    assert tids["main_work"] != tids["thread_work"]


def test_device_step_timer_disabled_and_enabled():
    t = T.DeviceStepTimer()
    t.note(out=None)                        # disabled: pure no-op
    t.flush("epoch")
    with T.enabled(None) as tr:
        for _ in range(3):
            t.note(out=None)
        t.flush(site="epoch")
        evs = tr.events()
    assert len(evs) == 1
    ev = evs[0]
    assert ev["name"] == "device_steps" and ev["cat"] == "device"
    assert ev["args"]["n"] == 3 and ev["args"]["site"] == "epoch"
    assert ev["args"]["per_step_us"] == pytest.approx(ev["dur"] / 3)
    # window resets after flush
    with T.enabled(None) as tr2:
        t.flush("epoch")
        assert tr2.events() == []


# ---------------------------------------------------------------------------
# analyzer arithmetic on synthetic span sets (times in us)
# ---------------------------------------------------------------------------
def _x(name, cat, ts, dur, tid=1, **args):
    return {"name": name, "cat": cat, "ph": "X", "ts": float(ts),
            "dur": float(dur), "pid": 7, "tid": tid, "args": args}


def test_interval_arithmetic():
    assert R.merge_intervals([(0, 10), (5, 15), (20, 30)]) == \
        [(0, 15), (20, 30)]
    assert R.intersect_total([(0, 10), (20, 30)], [(5, 25)]) == 10.0
    assert R.intersect_total([(0, 10)], [(10, 20)]) == 0.0


def test_overlap_fraction_synthetic():
    evs = [_x("producer_build", "producer", 0, 10, tid=2),
           _x("producer_build", "producer", 20, 10, tid=2),
           _x("train_step", "step", 5, 20, tid=1)]
    ov = R.overlap_fraction(evs)
    # producer busy [0,10]+[20,30]=20us; steps [5,25]; overlap 5+5=10us
    assert ov["producer_busy_s"] == pytest.approx(20 / 1e6)
    assert ov["overlap_s"] == pytest.approx(10 / 1e6)
    assert ov["overlap_frac"] == pytest.approx(0.5)


def test_sync_pipeline_overlap_is_zero_by_construction():
    evs = [_x("train_step", "step", 0, 10),
           _x("epoch_flush", "sync", 10, 2)]
    ov = R.overlap_fraction(evs)
    assert ov["producer_busy_s"] == 0.0 and ov["overlap_frac"] == 0.0


def test_stall_attribution_synthetic():
    evs = [_x("queue_get_wait", "wait", 0, 30),
           _x("queue_get_wait", "wait", 50, 10),
           _x("queue_put_wait", "wait", 60, 40)]
    st = R.stall_attribution(evs)
    assert st["queue_get_wait"]["count"] == 2
    assert st["queue_get_wait"]["total_s"] == pytest.approx(40 / 1e6)
    # wall is [0, 100]
    assert st["queue_put_wait"]["frac_of_wall"] == pytest.approx(0.4)


def test_epoch_rollups_and_mid_epoch_sync_gate():
    evs = [
        _x("epoch", "loop", 0, 100, epoch=0),
        _x("train_step", "step", 0, 20, step=0),
        _x("guard_sync", "sync", 25, 5),        # BEFORE last step: mid
        _x("train_step", "step", 40, 20, step=1),
        _x("cache_refill", "sync", 45, 5),      # inside last step: boundary
        _x("epoch_flush", "sync", 62, 5),       # after last step: boundary
    ]
    eps = R.epoch_rollups(evs)
    assert len(eps) == 1
    ep = eps[0]
    assert ep["epoch"] == 0 and ep["n_steps"] == 2
    assert ep["mid_epoch_syncs"] == 1
    assert ep["mid_epoch_sync_names"] == ["guard_sync"]
    assert ep["spans"]["train_step"]["count"] == 2
    rep = R.analyze(evs)
    assert rep["mid_epoch_sync_count"] == 1
    assert rep["sync_sites"]["epoch_flush"]["count"] == 1


def test_epoch_rollup_empty_epoch_has_no_mid_syncs():
    evs = [_x("epoch", "loop", 0, 10, epoch=4),
           _x("stats_flush", "sync", 2, 1)]
    (ep,) = R.epoch_rollups(evs)
    assert ep["n_steps"] == 0 and ep["mid_epoch_syncs"] == 0


# ---------------------------------------------------------------------------
# per-replica (per-pid) rollups: one Perfetto track per mesh replica
# ---------------------------------------------------------------------------
def _xp(pid, name, cat, ts, dur, **args):
    ev = _x(name, cat, ts, dur, **args)
    ev["pid"] = pid
    return ev


def test_per_pid_gate_fails_on_dirty_rank1_even_when_rank0_clean():
    """Two replica tracks over the same wall window. Rank 0 (pid 7) is
    clean. Rank 1 (pid 1001) has a sync at ts=50 — AFTER rank 0's last
    step start (20), so a pid-blind rollup would call it
    boundary-placed, but BEFORE rank 1's own last step (80): judged per
    pid it is mid-epoch and the gate must fail."""
    evs = [
        # rank 0: both syncs at/after its last step start -> boundary
        _xp(7, "epoch", "loop", 0, 100, epoch=0),
        _xp(7, "train_step", "step", 0, 15, step=0),
        _xp(7, "train_step", "step", 20, 15, step=1),
        _xp(7, "epoch_flush", "sync", 36, 4, epoch=0),
        # rank 1: same epoch envelope, later final step, early sync
        _xp(1001, "epoch", "loop", 0, 100, epoch=0),
        _xp(1001, "train_step", "step", 0, 15, step=0),
        _xp(1001, "halo_wait", "sync", 50, 5),
        _xp(1001, "train_step", "step", 80, 15, step=1),
        _xp(1001, "epoch_flush", "sync", 96, 4, epoch=0),
    ]
    eps = {ep["pid"]: ep for ep in R.epoch_rollups(evs)}
    assert set(eps) == {7, 1001}
    # rank 0 judged against ITS OWN steps only: rank 1's ts=80 step must
    # not drag rank 0's flush (ts=36) into mid-epoch territory...
    assert eps[7]["mid_epoch_syncs"] == 0
    assert eps[7]["n_steps"] == 2
    assert eps[7]["spans"]["train_step"]["count"] == 2  # not 4
    # ...and rank 1's early sync cannot hide behind rank 0's clean track
    assert eps[1001]["mid_epoch_syncs"] == 1
    assert eps[1001]["mid_epoch_sync_names"] == ["halo_wait"]
    rep = R.analyze(evs)
    assert rep["mid_epoch_sync_count"] == 1          # the gate fails
    assert rep["mid_epoch_sync_by_pid"] == {"7": 0, "1001": 1}


def test_replica_trace_emitter_tracks_pass_per_pid_gate():
    """`dist.gnn.ReplicaTraceEmitter` + `Tracer.for_replica` end to end
    on synthetic aux: distinct pid per replica, per-replica loss shares
    on the spans, rollup instants with the halo-bytes model, and every
    replica's reconstructed timeline passes the per-pid gate."""
    from repro.dist import gnn as dist_gnn
    hplan = dist_gnn.HaloPlan("halo", 1, 8)
    em = dist_gnn.ReplicaTraceEmitter(2, hplan, 8, 4)
    aux0 = {"loss": np.array([0.5, 0.25]), "dropped": np.array([0, 3]),
            "hits": np.array([2, 0]), "misses": np.array([1, 4])}
    aux1 = {"loss": np.array([0.4, 0.2]), "dropped": np.array([0, 1]),
            "hits": np.array([5, 0]), "misses": np.array([0, 2])}
    with T.enabled(None) as tr:
        em.note(0.0, 10.0, 0, aux0)
        em.note(20.0, 10.0, 1, aux1)
        em.flush(tr, epoch=0)
        assert em._steps == [] and em._aux == []     # drained
        evs = tr.events()
    pids = {e["pid"] for e in evs}
    assert len(pids) == 2 and tr.pid not in pids
    steps = [e for e in evs if e["name"] == "train_step"]
    assert len(steps) == 4                           # 2 steps x 2 replicas
    by_r = {}
    for e in steps:
        by_r.setdefault(e["args"]["replica"], []).append(e)
    assert by_r[0][0]["args"]["loss_share"] == pytest.approx(0.5)
    assert by_r[1][1]["args"]["loss_share"] == pytest.approx(0.2)
    roll = {e["args"]["replica"]: e["args"] for e in evs
            if e["name"] == "replica_rollup"}
    assert roll[1]["halo_dropped"] == 4
    assert roll[0]["cache_hits"] == 7 and roll[0]["cache_misses"] == 1
    assert roll[0]["halo_bytes"] == 2 * hplan.bytes_per_gather(8, 4, 2)
    # each replica's track is a well-formed epoch that passes the gate
    eps = R.epoch_rollups(evs)
    assert len(eps) == 2
    for ep in eps:
        assert ep["n_steps"] == 2 and ep["mid_epoch_syncs"] == 0
    rep = R.analyze(evs)
    assert rep["mid_epoch_sync_count"] == 0
    assert set(rep["mid_epoch_sync_by_pid"].values()) == {0}


def test_replica_emitter_without_tracer_is_noop():
    from repro.dist import gnn as dist_gnn
    em = dist_gnn.ReplicaTraceEmitter(2, dist_gnn.HaloPlan("halo", 0, 8),
                                      8, 4)
    em.note(0.0, 1.0, 0, {"loss": np.zeros(2), "dropped": np.zeros(2),
                          "hits": np.zeros(2), "misses": np.zeros(2)})
    em.flush(None, epoch=0)                          # no tracer: swallowed
    assert em._steps == []


# ---------------------------------------------------------------------------
# metrics hub
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram_primitives():
    h = M.MetricsHub()
    c = h.counter("c")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    h.gauge("g").set(2.5)
    assert h.gauge("g").value == 2.5
    hist = h.histogram("h")
    for v in [5.0, 1.0, 3.0, 2.0, 4.0]:
        hist.observe(v)
    s = hist.summary()
    assert s["count"] == 5 and s["min"] == 1.0 and s["max"] == 5.0
    assert s["p50"] == 3.0
    assert hist.percentile(0) == 1.0 and hist.percentile(100) == 5.0
    assert M.Histogram("empty").summary()["count"] == 0


def test_hub_name_bound_to_one_type():
    h = M.MetricsHub()
    h.counter("x")
    with pytest.raises(TypeError):
        h.gauge("x")
    assert h.counter("x") is h.counter("x")     # get-or-create


def test_hub_epoch_marks_are_counter_deltas():
    h = M.MetricsHub()
    h.counter("hits").inc(10)
    h.gauge("rate").set(0.5)
    e0 = h.mark_epoch(0)
    h.counter("hits").inc(3)
    h.gauge("rate").set(0.7)
    e1 = h.mark_epoch(1)
    assert e0["hits"] == 10 and e1["hits"] == 3      # delta, not total
    assert e1["rate"] == 0.7
    assert h.epochs == [e0, e1]
    assert h.snapshot()["hits"] == 13                # totals unharmed


def test_export_schema_and_run_metadata():
    h = M.MetricsHub()
    h.counter("n").inc()
    h.mark_epoch(0)
    out = h.export(extra={"tag": "unit"})
    assert out["schema"] == M.OBS_SCHEMA_VERSION
    assert out["metrics"]["n"] == 1
    assert len(out["epochs"]) == 1
    assert out["tag"] == "unit"
    meta = out["meta"]
    for k in ("schema", "backend", "jax", "git_commit", "hostname",
              "python"):
        assert k in meta, k
    assert meta["backend"] == jax.default_backend()
    json.dumps(out)                                  # JSON-serializable


# ---------------------------------------------------------------------------
# meter absorption: hub mirrors == meter fields, exactly
# ---------------------------------------------------------------------------
def test_hitrate_meter_mirrors_hub():
    h = M.MetricsHub()
    m = HitRateMeter(hub=h)
    m.observe(7, 3)
    m.observe(5, 5)
    m.observe_refill(40)
    m.note_degraded(step=9)
    assert h.counter("cache/hits").value == m.hits == 12
    assert h.counter("cache/misses").value == m.misses == 8
    assert h.counter("cache/refills").value == m.refills == 40
    assert h.counter("cache/degradations").value == 1
    assert h.gauge("cache/hit_rate").value == m.hit_rate


def test_resilience_meter_mirrors_hub():
    h = M.MetricsHub()
    m = ResilienceMeter(hub=h)
    m.note("rollbacks", step=3)
    m.note("skipped_steps", step=1)
    m.note("skipped_steps", step=2)
    assert h.counter("resilience/rollbacks").value == m.rollbacks == 1
    assert h.counter("resilience/skipped_steps").value \
        == m.skipped_steps == 2


def test_straggler_monitor_mirrors_hub_and_windows():
    h = M.MetricsHub()
    m = StragglerMonitor(warmup=2, threshold=2.0, hub=h)
    for _ in range(4):
        m.observe(0.01, 0)              # warmup + 2 normal
    mark = m.mark()
    m.observe(10.0, 4)                  # straggler
    m.observe(0.01, 5)
    assert h.counter("straggler/steps").value == m.count == 6
    assert h.counter("straggler/events").value == len(m.events) == 1
    assert h.gauge("straggler/fraction").value == m.straggler_fraction
    assert h.histogram("straggler/step_time_s").count == 6
    # per-epoch window: 1 straggler of the 2 steps since mark
    assert m.fraction_since(mark) == pytest.approx(0.5)
    assert m.fraction_since(m.mark()) == 0.0


def test_meter_absorption_on_real_trainer_run(tiny_graph):
    """20-step guarded dynamic-cache run: every hub series equals the
    legacy meter's own fields — the absorption is exact, not approximate."""
    tr = soak.make_trainer(tiny_graph, pipeline="sync", ckpt_dir=None,
                           ckpt_every=0)
    tr.train_steps(20)
    hub = tr.hub
    assert hub.counter("cache/hits").value == tr.cache_meter.hits
    assert hub.counter("cache/misses").value == tr.cache_meter.misses
    assert hub.counter("cache/refills").value == tr.cache_meter.refills
    assert hub.gauge("cache/hit_rate").value == tr.cache_meter.hit_rate
    assert hub.counter("straggler/steps").value == tr.straggler.count == 20
    assert hub.gauge("straggler/fraction").value \
        == tr.straggler.straggler_fraction
    for kind, n in tr.guard_meter.counts().items():
        assert hub.counter(f"resilience/{kind}").value == n
    out = hub.export()
    assert out["schema"] == M.OBS_SCHEMA_VERSION
    assert out["metrics"]["cache/hits"] == tr.cache_meter.hits


# ---------------------------------------------------------------------------
# trainer integration: spans, straggler surfacing, bit-exactness
# ---------------------------------------------------------------------------
def test_tracing_on_off_loss_bit_exact(tiny_graph):
    """The zero-cost contract: the traced run's losses are bit-identical
    to the untraced run's (tracing touches no RNG, data, or sync)."""
    tr1 = soak.make_trainer(tiny_graph, pipeline="sync", ckpt_dir=None,
                            ckpt_every=0, guard=None)
    with T.enabled(None):
        traced = tr1.train_steps(6)
    tr2 = soak.make_trainer(tiny_graph, pipeline="sync", ckpt_dir=None,
                            ckpt_every=0, guard=None)
    untraced = tr2.train_steps(6)
    assert traced == untraced           # exact float equality, per step


def test_trainer_emits_expected_span_taxonomy(tiny_graph):
    tr = soak.make_trainer(tiny_graph, pipeline="sync", ckpt_dir=None,
                           ckpt_every=0)
    with T.enabled(None) as tracer:
        d = tr.run_epoch(1e-3)
        evs = tracer.events()
    names = {e["name"] for e in evs}
    assert {"train_step", "epoch", "epoch_flush", "device_steps",
            "guard_sync", "stats_flush"} <= names
    # straggler fraction surfaced through the epoch dict
    assert 0.0 <= d["straggler"] <= 1.0
    # the device window covers every step of the epoch
    (dev,) = [e for e in evs if e["name"] == "device_steps"]
    n_steps = len([e for e in evs if e["name"] == "train_step"])
    assert dev["args"]["n"] == n_steps and dev["args"]["site"] == "epoch"
    # trainer-side per-epoch snapshot landed in the hub
    assert tr.hub.epochs and tr.hub.epochs[-1]["epoch"] == 0


def test_epoch_metrics_has_straggler_field(tiny_graph):
    from repro.train.gnn_loop import EpochMetrics
    em = EpochMetrics(0, 1.0, 1.0, 0.5, 1.0, 10.0)
    assert em.straggler_fraction == 0.0     # default: no monitor data


def test_checkpoint_spans(tiny_graph, tmp_path):
    from repro.train import checkpoint as ckpt
    tree = {"w": np.arange(4, dtype=np.float32)}
    with T.enabled(None) as tracer:
        ckpt.save(str(tmp_path), 3, tree)
        ckpt.restore(str(tmp_path), 3, tree)
        evs = tracer.events()
    by_name = {e["name"]: e for e in evs}
    assert by_name["ckpt_save"]["cat"] == "sync"
    assert by_name["ckpt_save"]["args"]["step"] == 3
    assert by_name["ckpt_restore"]["cat"] == "ckpt"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def _write_trace(path, events):
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")


def test_cli_report_and_gates(tmp_path, capsys):
    good = [
        _x("epoch", "loop", 0, 100, epoch=0),
        _x("producer_build", "producer", 0, 40, tid=2),
        _x("train_step", "step", 10, 30, step=0),
        _x("epoch_flush", "sync", 45, 5),
    ]
    p = str(tmp_path / "good.jsonl")
    _write_trace(p, good)
    out_json = str(tmp_path / "r.json")
    out_chrome = str(tmp_path / "c.json")
    rc = obs_cli([p, "--json", out_json, "--chrome", out_chrome,
                  "--require-overlap", "--forbid-mid-epoch-sync"])
    assert rc == 0
    rep = json.loads(open(out_json).read())
    assert rep["overlap"]["overlap_frac"] > 0
    assert rep["mid_epoch_sync_count"] == 0
    assert "traceEvents" in json.loads(open(out_chrome).read())

    # no producer spans -> --require-overlap fails
    sync_only = [_x("train_step", "step", 0, 10)]
    p2 = str(tmp_path / "sync.jsonl")
    _write_trace(p2, sync_only)
    assert obs_cli([p2, "--require-overlap"]) == 1
    assert obs_cli([p2]) == 0

    # a mid-epoch sync -> --forbid-mid-epoch-sync fails
    midsync = [
        _x("epoch", "loop", 0, 100, epoch=0),
        _x("train_step", "step", 0, 10, step=0),
        _x("guard_sync", "sync", 15, 2),
        _x("train_step", "step", 30, 10, step=1),
    ]
    p3 = str(tmp_path / "mid.jsonl")
    _write_trace(p3, midsync)
    assert obs_cli([p3, "--forbid-mid-epoch-sync"]) == 1
    assert obs_cli([p3]) == 0
    capsys.readouterr()
