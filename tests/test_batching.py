"""The `repro.batching` subsystem: policy registry invariants, bit-exact
cursor resume, calibrator caching, and GNNTrainer checkpoint round-trips."""
import numpy as np
import pytest

from repro import batching
from repro.batching import (BatchStream, CapsCalibrator, Cursor,
                            available_policies, make_policy, root_batches)
from repro.core import partition


FANOUTS = (5, 5)
CAPS = (1024, 1536)


# ---------------------------------------------------------------------------
# registry / policies
# ---------------------------------------------------------------------------
def test_registry_has_all_paper_policies():
    assert set(available_policies()) >= {"rand", "norand", "comm_rand",
                                         "clustergcn", "labor"}


@pytest.mark.parametrize("name", ["rand", "norand", "comm_rand",
                                  "clustergcn", "labor"])
def test_every_registered_policy_yields_a_permutation(name, tiny_graph):
    g = tiny_graph
    pol = make_policy(name)
    rng = np.random.default_rng(0)
    order = pol.epoch_order(g.train_ids, g.communities, rng)
    assert np.array_equal(np.sort(order), np.sort(g.train_ids))
    assert pol.describe()
    assert 0.0 <= pol.p <= 1.0


def test_commrand_mix1_matches_rand_label_diversity(tiny_graph):
    """mix=1.0 merges every community into ONE super-block, i.e. a full
    uniform shuffle: its per-batch label diversity matches rand's."""
    g = tiny_graph
    div = {}
    for name, pol in [("rand", make_policy("rand")),
                      ("mix1", make_policy("comm_rand", mix=1.0, p=0.5))]:
        labs = [partition.labels_per_batch(
            root_batches(g, pol, 128, seed=s), g.labels) for s in range(4)]
        div[name] = float(np.mean(labs))
    assert div["mix1"] == pytest.approx(div["rand"], rel=0.05)


def test_root_batches_matches_partition_shim(tiny_graph):
    """Old entry point (core.partition) and new API agree batch-for-batch."""
    g = tiny_graph
    pol = make_policy("comm_rand", mix=0.125, p=1.0)
    new = root_batches(g, pol, 256, seed=3, epoch=2)
    old = partition.batches_for_epoch(
        g.train_ids, g.communities, pol, 256,
        np.random.default_rng((3, 2)))
    assert np.array_equal(new, old)


def test_blockshuffler_uses_shared_operator():
    """data.pipeline.BlockShuffler == batching.block_shuffle bit-for-bit."""
    from repro.data.pipeline import BlockShuffler
    sh = BlockShuffler(100, 10, mix=0.25, mode="block", seed=5)
    got = sh.epoch_order(3)
    rng = np.random.default_rng((5, 3))
    want = batching.block_shuffle(
        np.array_split(np.arange(100), 10), 0.25, rng)
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# BatchStream cursor resume
# ---------------------------------------------------------------------------
def _stream(g, cursor=None, seed=7):
    return BatchStream(g, make_policy("comm_rand", mix=0.125, p=1.0), 256,
                       FANOUTS, CAPS, seed=seed, cursor=cursor)


def _assert_batches_equal(a, b):
    for la, lb in zip(a.levels, b.levels):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))
    np.testing.assert_array_equal(np.asarray(a.label_mask),
                                  np.asarray(b.label_mask))


def test_batchstream_cursor_resume_is_bit_exact(tiny_graph):
    s1 = _stream(tiny_graph)
    it1 = iter(s1)
    for _ in range(3):
        next(it1)                             # consume 3 batches
    saved = Cursor.from_state(s1.cursor.state())

    s2 = _stream(tiny_graph, cursor=saved)    # fresh stream, restored cursor
    it2 = iter(s2)
    for _ in range(4):                        # crosses the epoch boundary
        _assert_batches_equal(next(it1), next(it2))
    assert s1.cursor.state() == s2.cursor.state()


def test_batchstream_epoch_covers_train_set_once(tiny_graph):
    s = _stream(tiny_graph)
    roots = []
    for b in s.epoch():
        lv = np.asarray(b.levels[0])
        roots.append(lv[np.asarray(b.label_mask)])
    assert np.array_equal(np.sort(np.concatenate(roots)),
                          np.sort(tiny_graph.train_ids))
    assert s.cursor.state() == {"epoch": 1, "pos": 0}


# ---------------------------------------------------------------------------
# CapsCalibrator cache
# ---------------------------------------------------------------------------
def test_capscalibrator_cache_hit_returns_identical_caps(tiny_graph,
                                                         tmp_path,
                                                         monkeypatch):
    path = str(tmp_path / "caps.json")
    pol = make_policy("comm_rand", mix=0.125, p=1.0)
    caps1 = CapsCalibrator(cache_path=path, n_probe=4).caps_for(
        tiny_graph, pol, 128, FANOUTS)

    # a cache hit must not re-run the probe
    from repro.core import minibatch as mb_mod

    def boom(*a, **k):
        raise AssertionError("probe ran on a cache hit")

    monkeypatch.setattr(mb_mod, "calibrate_caps", boom)
    caps2 = CapsCalibrator(cache_path=path, n_probe=4).caps_for(
        tiny_graph, pol, 128, FANOUTS)
    assert caps1 == caps2

    # different knobs -> different key -> probe would run again
    with pytest.raises(AssertionError):
        CapsCalibrator(cache_path=path, n_probe=4).caps_for(
            tiny_graph, pol, 64, FANOUTS)


def test_calibrate_probes_are_spread_across_epoch(tiny_graph):
    """The probe-bias fix: comm_rand caps must hold for LATE (mixed)
    batches, not just the community-pure leading ones."""
    from repro.core.minibatch import build_batch_np, calibrate_caps
    pol = make_policy("comm_rand", mix=0.25, p=1.0)
    caps = calibrate_caps(tiny_graph, pol, 128, FANOUTS, n_probe=6)
    rng = np.random.default_rng(11)
    batches = partition.batches_for_epoch(
        tiny_graph.train_ids, tiny_graph.communities, pol, 128, rng)
    sizes, _ = build_batch_np(rng, tiny_graph, batches[-1], FANOUTS, pol.p)
    assert sizes[-1] <= caps[-1]


# ---------------------------------------------------------------------------
# GNNTrainer checkpoint round-trip (ISSUE acceptance)
# ---------------------------------------------------------------------------
def test_gnn_trainer_cursor_roundtrips_through_checkpoint(tiny_graph,
                                                          tmp_path):
    import jax
    from repro.configs.base import GNNConfig, TrainConfig
    from repro.train.gnn_loop import GNNTrainer

    g = tiny_graph
    cfg = GNNConfig("sage-ckpt", "sage", 2, 32, g.feat_dim, g.num_classes,
                    fanout=FANOUTS)
    tcfg = TrainConfig(batch_size=256, max_epochs=4)
    d = str(tmp_path / "ckpt")

    tr1 = GNNTrainer(g, cfg, tcfg, make_policy("comm_rand", mix=0.125, p=1.0),
                     caps=CAPS, eval_caps=CAPS, seed=0, ckpt_dir=d)
    tr1.train_steps(3)
    tr1.save()                                # mid-epoch checkpoint
    saved_cursor = tr1.stream.cursor.state()
    cont1 = tr1.train_steps(2)                # ground-truth continuation

    tr2 = GNNTrainer(g, cfg, tcfg, make_policy("comm_rand", mix=0.125, p=1.0),
                     caps=CAPS, eval_caps=CAPS, seed=0, ckpt_dir=d)
    assert tr2.global_step == 3
    assert tr2.stream.cursor.state() == saved_cursor
    for a, b in zip(jax.tree.leaves(tr1.opt_state),
                    jax.tree.leaves(tr2.opt_state)):
        assert np.asarray(a).shape == np.asarray(b).shape
    cont2 = tr2.train_steps(2)
    # bit-exact: same batches, same dropout keys, same arithmetic
    assert cont1 == cont2
