"""Biased neighborhood sampling (paper §4.2): probability + validity
properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sampler import sample_neighbors
from repro.graphs.csr import DeviceGraph


@pytest.fixture(scope="module")
def gdev(tiny_graph):
    return DeviceGraph.from_graph(tiny_graph)


def test_sampled_edges_exist(gdev, tiny_graph):
    nodes = jnp.asarray(tiny_graph.train_ids[:64], jnp.int32)
    srcs, mask = sample_neighbors(jax.random.key(0), gdev, nodes, 10, 0.5)
    srcs, mask = np.asarray(srcs), np.asarray(mask)
    g = tiny_graph
    for i, u in enumerate(np.asarray(nodes)):
        nbrs = set(g.indices[g.indptr[u]:g.indptr[u + 1]])
        for j in range(10):
            if mask[i, j]:
                assert int(srcs[i, j]) in nbrs or int(srcs[i, j]) == u


def test_p1_selects_only_intra(gdev, tiny_graph):
    g = tiny_graph
    # nodes that have at least one intra neighbor
    cand = np.where(g.n_intra > 0)[0][:128]
    nodes = jnp.asarray(cand, jnp.int32)
    srcs, mask = sample_neighbors(jax.random.key(1), gdev, nodes, 10, 1.0)
    srcs, mask = np.asarray(srcs), np.asarray(mask)
    comm = g.communities
    same = comm[srcs] == comm[np.asarray(nodes)][:, None]
    assert same[mask].all()


def test_p05_is_unbiased(gdev, tiny_graph):
    """p=0.5 must be (near) uniform over neighbors: intra fraction of
    samples ~ intra fraction of edges."""
    g = tiny_graph
    cand = np.where((g.n_intra > 2) & (g.degrees() - g.n_intra > 2))[0][:64]
    nodes = jnp.asarray(np.repeat(cand, 8), jnp.int32)
    srcs, mask = sample_neighbors(jax.random.key(2), gdev, nodes, 16, 0.5)
    srcs = np.asarray(srcs)
    nodes_np = np.asarray(nodes)
    same = (g.communities[srcs] == g.communities[nodes_np][:, None]).mean()
    exp = (g.n_intra[cand] / g.degrees()[cand]).mean()
    assert abs(same - exp) < 0.05, (same, exp)


def test_sentinel_and_isolated(gdev, tiny_graph):
    N = tiny_graph.num_nodes
    nodes = jnp.asarray([N, N, 5], jnp.int32)   # two padded + one real
    srcs, mask = sample_neighbors(jax.random.key(3), gdev, nodes, 4, 0.9)
    assert (np.asarray(srcs[:2]) == N).all()
    assert not np.asarray(mask[:2]).any()


def test_mode_all_enumerates_neighbors(gdev, tiny_graph):
    g = tiny_graph
    u = int(g.train_ids[0])
    deg = int(g.degrees()[u])
    fan = deg + 4
    srcs, mask = sample_neighbors(jax.random.key(4), gdev,
                                  jnp.asarray([u], jnp.int32), fan, 0.5,
                                  mode="all")
    got = set(np.asarray(srcs)[0][np.asarray(mask)[0]].tolist())
    want = set(g.indices[g.indptr[u]:g.indptr[u + 1]].tolist())
    assert got == want
    assert int(np.asarray(mask).sum()) == deg


@settings(max_examples=10, deadline=None)
@given(p=st.floats(0.5, 1.0), seed=st.integers(0, 50), fanout=st.sampled_from([1, 5, 13]))
def test_shapes_and_determinism(gdev, p, seed, fanout):
    nodes = jnp.arange(32, dtype=jnp.int32)
    s1, m1 = sample_neighbors(jax.random.key(seed), gdev, nodes, fanout, p)
    s2, m2 = sample_neighbors(jax.random.key(seed), gdev, nodes, fanout, p)
    assert s1.shape == (32, fanout)
    assert (np.asarray(s1) == np.asarray(s2)).all()
    assert (np.asarray(m1) == np.asarray(m2)).all()
