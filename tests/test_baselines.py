"""Prior-work baselines (paper §2 / §6.3 / Fig 8 directions)."""
import numpy as np
import pytest

from repro.configs.base import GNNConfig, TrainConfig
from repro.core import partition
from repro.configs.base import CommRandPolicy
from repro.train.baselines import (clustergcn_batches, induced_subgraph,
                                   labor_lite_epoch_footprint,
                                   train_clustergcn, train_fullbatch)


@pytest.fixture(scope="module")
def cfg(tiny_graph):
    g = tiny_graph
    return GNNConfig("sage-b", "sage", 2, 32, g.feat_dim, g.num_classes,
                     fanout=(5, 5))


def test_clustergcn_batches_cover_graph(tiny_graph):
    rng = np.random.default_rng(0)
    parts = clustergcn_batches(tiny_graph, 2, rng)
    allnodes = np.concatenate(parts)
    assert len(np.unique(allnodes)) == tiny_graph.num_nodes


def test_induced_subgraph_edges_are_real(tiny_graph):
    rng = np.random.default_rng(0)
    part = clustergcn_batches(tiny_graph, 2, rng)[0]
    sb = induced_subgraph(tiny_graph, part, len(part) + 8,
                          len(part) * 40)
    nodes = np.asarray(sb.nodes)
    es, ed, em = (np.asarray(sb.edge_src), np.asarray(sb.edge_dst),
                  np.asarray(sb.edge_mask))
    g = tiny_graph
    for s, d in zip(es[em][:200], ed[em][:200]):
        u, v = nodes[d], nodes[s]
        nbrs = g.indices[g.indptr[u]:g.indptr[u + 1]]
        assert v in nbrs


def test_clustergcn_trains(tiny_graph, cfg):
    # ClusterGCN converges slower than COMM-RAND (paper §6.3) — give it a
    # few more epochs than the mini-batch tests use.
    r = train_clustergcn(tiny_graph, cfg, TrainConfig(max_epochs=10),
                         parts_per_batch=2, epochs=10)
    assert np.isfinite(r["loss"])
    assert r["val_acc"] > 0.6


def test_clustergcn_epoch_time_invariant_to_train_size(tiny_graph, cfg):
    """Paper Fig 8: ClusterGCN computes the whole graph regardless of the
    training-set size."""
    import dataclasses
    g_small = dataclasses.replace(tiny_graph,
                                  train_ids=tiny_graph.train_ids[:50])
    r_full = train_clustergcn(tiny_graph, cfg, TrainConfig(), epochs=2)
    r_small = train_clustergcn(g_small, cfg, TrainConfig(), epochs=2)
    ratio = r_small["per_epoch_time_s"] / r_full["per_epoch_time_s"]
    assert 0.5 < ratio < 2.0    # invariant (vs ~26x smaller train set)


def test_fullbatch_trains_and_steps_once_per_epoch(tiny_graph, cfg):
    r = train_fullbatch(tiny_graph, cfg, TrainConfig(), epochs=4)
    assert len(r["val_acc_curve"]) == 4
    assert r["per_epoch_time_s"] > 0


def test_labor_lite_footprint_between_rand_and_commrand(tiny_graph):
    """LABOR's dependent sampling shrinks the footprint vs iid uniform, but
    less than community bias (paper §6.3)."""
    g = tiny_graph
    rng = np.random.default_rng(0)
    batches = partition.batches_for_epoch(
        g.train_ids, g.communities, CommRandPolicy("rand"), 256, rng)[:3]
    labor = labor_lite_epoch_footprint(g, batches, (5, 5))
    # iid-uniform footprint, measured through the same numpy path
    from repro.core.minibatch import build_batch_np
    iid = np.mean([build_batch_np(np.random.default_rng(i), g, b, (5, 5),
                                  0.5)[0][-1]
                   for i, b in enumerate(batches)])
    assert labor < iid
