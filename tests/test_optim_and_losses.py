"""Optimizer, schedules, losses."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import adamw
from repro.optim.schedule import EarlyStopping, ReduceLROnPlateau
from repro.train.losses import chunked_cross_entropy, gnn_softmax_ce


def _np_adamw(g, m, v, p, t, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    return p - lr * (mh / (np.sqrt(vh) + eps) + wd * p), m, v


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p0 = rng.normal(size=(7, 5)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = adamw.init(params)
    p_np, m_np, v_np = p0.copy(), np.zeros_like(p0), np.zeros_like(p0)
    for t in range(1, 5):
        g = rng.normal(size=p0.shape).astype(np.float32)
        params, state = adamw.update({"w": jnp.asarray(g)}, state, params,
                                     lr=1e-2, weight_decay=0.1)
        p_np, m_np, v_np = _np_adamw(g, m_np, v_np, p_np, t, 1e-2, wd=0.1)
        np.testing.assert_allclose(np.asarray(params["w"]), p_np, rtol=2e-5,
                                   atol=2e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 20


def test_reduce_lr_on_plateau_mirrors_paper_settings():
    s = ReduceLROnPlateau(1e-3, factor=0.1, patience=3)
    lr = 1e-3
    for i in range(5):
        lr = s.step(1.0)     # no improvement
    assert abs(lr - 1e-4) < 1e-12
    lr = s.step(0.5)         # improvement resets
    assert abs(lr - 1e-4) < 1e-12


def test_early_stopping_patience():
    es = EarlyStopping(patience=3)
    assert not es.update(1.0, 0)
    assert not es.update(0.9, 1)
    assert not es.update(0.95, 2)
    assert not es.update(0.95, 3)
    assert es.update(0.95, 4)
    assert es.best_epoch == 1


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([1, 3]), s=st.sampled_from([8, 32]),
       v=st.sampled_from([64, 100]), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 20))
def test_chunked_ce_matches_direct(b, s, v, chunk, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    hidden = jax.random.normal(ks[0], (b, s, 16))
    head = jax.random.normal(ks[1], (16, v))
    labels = jax.random.randint(ks[2], (b, s), 0, v)
    got = chunked_cross_entropy(hidden, head, labels, chunk=chunk)
    logits = hidden @ head
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = (lse - picked).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_ce_grads_match_direct():
    ks = jax.random.split(jax.random.key(3), 3)
    hidden = jax.random.normal(ks[0], (2, 16, 8))
    head = jax.random.normal(ks[1], (8, 50))
    labels = jax.random.randint(ks[2], (2, 16), 0, 50)

    def direct(h, w):
        logits = h @ w
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return (lse - picked).mean()

    g1 = jax.grad(lambda h, w: chunked_cross_entropy(h, w, labels, chunk=8),
                  argnums=(0, 1))(hidden, head)
    g2 = jax.grad(direct, argnums=(0, 1))(hidden, head)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-6)


def test_gnn_ce_ignores_masked():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0]])
    labels = jnp.asarray([0, 0])
    m_all = gnn_softmax_ce(logits, labels, jnp.asarray([1.0, 1.0]))
    m_first = gnn_softmax_ce(logits, labels, jnp.asarray([1.0, 0.0]))
    assert m_first < m_all
