"""Community detection + reordering."""
import numpy as np

from repro.core import community, reorder
from repro.graphs import synthetic
from repro.graphs.csr import intra_first_layout


def test_louvain_recovers_sbm_structure():
    g = synthetic.load("tiny")
    comm = community.louvain(g.indptr, g.indices, seed=0)
    q = community.modularity(g.indptr, g.indices, comm)
    q_oracle = community.modularity(g.indptr, g.indices, g.communities)
    assert q > 0.8 * q_oracle, (q, q_oracle)


def test_modularity_of_random_assignment_is_low():
    g = synthetic.load("tiny")
    rng = np.random.default_rng(0)
    rand = rng.integers(0, 8, g.num_nodes).astype(np.int32)
    assert community.modularity(g.indptr, g.indices, rand) < 0.05


def test_reorder_makes_communities_contiguous():
    g = reorder.prepare(synthetic.load("tiny"), oracle=True)
    comm = g.communities
    # contiguous: community id changes at most n_comm-1 times
    changes = np.sum(np.diff(comm) != 0)
    assert changes == comm.max()


def test_reorder_preserves_graph():
    g = synthetic.load("tiny")
    g2 = reorder.prepare(g, oracle=True)
    assert g2.num_nodes == g.num_nodes
    assert g2.num_edges == g.num_edges
    assert np.array_equal(np.sort(g2.degrees()), np.sort(g.degrees()))
    # labels follow their nodes: class histograms identical
    assert np.array_equal(np.bincount(g2.labels), np.bincount(g.labels))


def test_intra_first_layout_counts():
    g = reorder.prepare(synthetic.load("tiny"), oracle=True)
    for u in range(0, g.num_nodes, 97):
        s, e = g.indptr[u], g.indptr[u + 1]
        nbrs = g.indices[s:e]
        intra = g.communities[nbrs] == g.communities[u]
        ni = g.n_intra[u]
        assert intra[:ni].all()
        assert not intra[ni:].any()
