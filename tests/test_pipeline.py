"""Host data pipeline: block shuffle invariants + resumable cursor."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import (BlockShuffler, Cursor, LMStream,
                                 SyntheticTokens)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 500), bs=st.integers(1, 64),
       mix=st.sampled_from([0.0, 0.125, 0.5]),
       mode=st.sampled_from(["rand", "block", "none"]),
       epoch=st.integers(0, 3))
def test_epoch_order_is_permutation(n, bs, mix, mode, epoch):
    sh = BlockShuffler(n, bs, mix, mode)
    order = sh.epoch_order(epoch)
    assert np.array_equal(np.sort(order), np.arange(n))


def test_block_mode_keeps_blocks_contiguous_when_mix_small():
    sh = BlockShuffler(100, 10, mix=0.0, mode="block")
    order = sh.epoch_order(0)
    blocks_seen = order // 10
    assert np.sum(np.diff(blocks_seen) != 0) == 9   # 10 contiguous blocks


def test_orders_differ_across_epochs_but_repeat_per_epoch():
    sh = BlockShuffler(64, 8, mode="block")
    a, b = sh.epoch_order(0), sh.epoch_order(1)
    assert not np.array_equal(a, b)
    assert np.array_equal(a, sh.epoch_order(0))


def test_stream_cursor_resume_exact():
    corpus = SyntheticTokens(512, num_docs=64, doc_len=40)
    s1 = LMStream(corpus, batch=4, seq=16)
    it1 = iter(s1)
    batches = [next(it1) for _ in range(10)]
    cur = Cursor.from_state(s1.cursor.state())
    # fresh stream resumed at the cursor reproduces the continuation
    s2 = LMStream(corpus, batch=4, seq=16, cursor=cur)
    it2 = iter(s2)
    n1 = next(it1)
    n2 = next(it2)
    assert np.array_equal(n1[0], n2[0]) and np.array_equal(n1[1], n2[1])


def test_labels_are_shifted_tokens():
    corpus = SyntheticTokens(512, num_docs=8, doc_len=40)
    toks, labels = next(iter(LMStream(corpus, batch=2, seq=16)))
    doc = np.resize(corpus.doc(int(0)), 17)
    # stream order is shuffled; just check shift-by-one within rows
    assert toks.shape == labels.shape == (2, 16)
