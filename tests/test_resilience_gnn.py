"""`repro.resilience` contract tests: deterministic fault plans, the
guarded GNN train step (skip / rollback), the async producer watchdog,
checkpoint integrity + fallback, caps-cache robustness, dynamic-cache
integrity degradation — and the headline chaos soak: one fault of every
class, each recovering onto a BIT-IDENTICAL loss trajectory."""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import featcache
from repro.batching import BatchStream, CapsCalibrator, make_policy
from repro.featcache import dynamic as fdyn
from repro.pipeline import AsyncBatchStream
from repro.resilience import (FaultPlan, FaultSpec, GuardConfig,
                              InjectedFault, as_guard, corrupt_checkpoint,
                              faults, soak)
from repro.train import checkpoint as ckpt
from repro.train.monitor import ResilienceMeter, StepFailure

BATCH, FANOUTS, CAPS = soak.BATCH, soak.FANOUTS, soak.CAPS


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------
def test_fault_plan_seeded_is_deterministic():
    windows = {"batch_build": (3, 9), "step_nonfinite": (0, 50)}
    p1 = FaultPlan.seeded(7, windows, {"step_nonfinite": 3})
    p2 = FaultPlan.seeded(7, windows, {"step_nonfinite": 3})
    assert p1.specs == p2.specs
    assert {s.site for s in p1.specs} == set(windows)
    for s in p1.specs:
        lo, hi = windows[s.site]
        assert lo <= s.start <= hi
    # the payload stream replays too: same (seed, site, start) -> same draws
    s = p1.specs[0]
    assert p1.payload_rng(s).integers(1 << 30) == \
        p2.payload_rng(s).integers(1 << 30)


def test_fault_plan_fire_window_and_events():
    plan = FaultPlan(specs=(FaultSpec("batch_build", 2, 2),))
    armed = [plan.fire("batch_build", pos=i) is not None for i in range(6)]
    assert armed == [False, False, True, True, False, False]
    assert [e["invocation"] for e in plan.fired("batch_build")] == [2, 3]
    assert plan.fired("ckpt_truncate") == []
    assert plan.counters["batch_build"] == 6


def test_inject_context_installs_and_restores():
    assert faults.active() is None
    plan = FaultPlan(specs=(FaultSpec("batch_build", 0),))
    with faults.inject(plan) as p:
        assert faults.active() is p
        with pytest.raises(InjectedFault):
            faults.maybe_raise("batch_build")
        faults.maybe_raise("batch_build")       # invocation 1: disarmed
    assert faults.active() is None
    faults.maybe_raise("batch_build")           # no plan: free no-op


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("no_such_site", 0)
    with pytest.raises(ValueError):
        FaultSpec("batch_build", -1)
    with pytest.raises(ValueError):
        FaultSpec("batch_build", 0, 0)
    with pytest.raises(ValueError):
        ResilienceMeter().note("no_such_kind")


def test_as_guard_normalization():
    assert as_guard(None) is None
    assert as_guard(False) is None
    assert as_guard(True) == GuardConfig()
    g = GuardConfig(max_consecutive_skips=1, check_every=2)
    assert as_guard(g) is g
    with pytest.raises(TypeError):
        as_guard("yes")
    with pytest.raises(ValueError):
        GuardConfig(max_consecutive_skips=-1)


# ---------------------------------------------------------------------------
# guarded train step: in-jit skip (no rollback)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("guard", [soak.GUARD, None])
def test_nonfinite_step_applies_no_update(tiny_graph, guard):
    """One poisoned step below the skip budget: the where-select keeps
    params/opt bit-identical (guard=None included — detection lives in
    the jitted step; the config only controls sync/escalation), and the
    next clean step trains normally from the untouched weights."""
    tr = soak.make_trainer(tiny_graph, pipeline="sync", ckpt_dir=None,
                           ckpt_every=0, guard=guard)
    tr.train_steps(1)                           # compile + one clean step
    before = jax.tree.map(lambda x: np.asarray(x), tr.params)
    plan = FaultPlan(specs=(FaultSpec("step_nonfinite", 0),))
    with faults.inject(plan):
        (bad,) = tr.train_steps(1)              # invocation 0: poisoned
        mid = jax.tree.map(lambda x: np.asarray(x), tr.params)
        (good,) = tr.train_steps(1)
    assert plan.fired("step_nonfinite")
    assert np.isnan(bad) and np.isfinite(good)
    after_skip_meter = tr.guard_meter.counts()
    assert after_skip_meter["rollbacks"] == 0
    if guard is not None:
        assert after_skip_meter["skipped_steps"] == 1
    else:
        assert after_skip_meter["skipped_steps"] == 0   # nothing synced
    # the poisoned step left the weights untouched; the clean one didn't
    assert _leaves_equal(before, mid)
    assert not _leaves_equal(mid, tr.params)


def test_skip_budget_without_ckpt_raises_stepfailure(tiny_graph):
    """Escalation with no ckpt_dir can't roll back — it must fail loudly
    (StepFailure), not train on from a poisoned trajectory."""
    tr = soak.make_trainer(tiny_graph, pipeline="sync", ckpt_dir=None,
                           ckpt_every=0)
    budget = soak.GUARD.max_consecutive_skips
    plan = FaultPlan(specs=(FaultSpec("step_nonfinite", 0, budget + 1),))
    with faults.inject(plan), pytest.raises(StepFailure):
        tr.train_steps(budget + 2)


# ---------------------------------------------------------------------------
# the headline chaos soak: every fault class, bit-exact recovery
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def soak_ref(tiny_graph):
    return soak.run_reference(tiny_graph, soak.N_STEPS)


@pytest.mark.parametrize("site", faults.FAULT_SITES)
def test_chaos_scenario_recovers_bit_exactly(tiny_graph, soak_ref, site):
    """Inject one seeded fault of this class into a guarded
    comm_rand x LABOR + dynamic-cache async run: the fault must fire,
    the matching recovery mechanism must engage, and the final loss
    trajectory AND parameter digest must be BIT-IDENTICAL to the
    fault-free sync reference."""
    res = soak.run_scenario(tiny_graph, site, ref=soak_ref)
    assert res.fired > 0, "fault never fired — the scenario proves nothing"
    assert res.recovered, f"expected recovery missing: {res.meter}"
    assert res.bitmatch, "loss trajectory diverged from fault-free run"
    assert res.digest_match, "final params differ from fault-free run"
    assert res.ok


# ---------------------------------------------------------------------------
# producer watchdog (dedicated stream-level tests)
# ---------------------------------------------------------------------------
def _streams(tiny_graph, seed=5, **kw):
    pol = make_policy("rand")
    sync = BatchStream(tiny_graph, pol, BATCH, FANOUTS, CAPS, seed=seed)
    asyn = AsyncBatchStream(tiny_graph, pol, BATCH, FANOUTS, CAPS,
                            seed=seed, restart_backoff_s=0.01, **kw)
    return sync, asyn


def test_watchdog_restarts_hung_producer(tiny_graph):
    """The producer stops heartbeating mid-epoch; the consumer's stall
    watchdog restarts it from the pending cursor and the delivered
    sequence stays bit-exact against the synchronous stream."""
    meter = ResilienceMeter()
    sync, asyn = _streams(tiny_graph, meter=meter)
    asyn.prime()
    asyn.stall_timeout_s = 0.4
    plan = FaultPlan(specs=(FaultSpec("producer_hang", 2),))
    try:
        with faults.inject(plan):
            it = iter(asyn)
            got = [next(it) for _ in range(6)]
    finally:
        asyn.close()
    assert plan.fired("producer_hang")
    assert asyn.restarts >= 1
    assert meter.producer_restarts >= 1
    for i, b in enumerate(got):
        want = sync.build(sync.root_batches(0)[i], 0, i)
        assert _leaves_equal(want, b), i


def test_watchdog_restarts_dead_producer_bit_exact(tiny_graph):
    """A transient build failure kills the producer thread; the watchdog
    restarts it from the same cursor — same batches, bit for bit."""
    meter = ResilienceMeter()
    sync, asyn = _streams(tiny_graph, meter=meter)
    plan = FaultPlan(specs=(FaultSpec("batch_build", 3),))
    try:
        with faults.inject(plan):
            it = iter(asyn)
            got = [next(it) for _ in range(6)]
    finally:
        asyn.close()
    assert plan.fired("batch_build")
    assert meter.producer_restarts == 1
    assert [e["reason"] for e in meter.events
            if e["kind"] == "producer_restarts"]
    for i, b in enumerate(got):
        want = sync.build(sync.root_batches(0)[i], 0, i)
        assert _leaves_equal(want, b), i


def test_persistent_producer_error_reraises_real_exception(tiny_graph):
    """Past the restart budget the consumer re-raises the producer's REAL
    stashed exception (InjectedFault here), not a generic 'producer
    died' wrapper — the satellite fix for the dropped-exception bug."""
    _, asyn = _streams(tiny_graph, max_restarts=1)
    plan = FaultPlan(specs=(FaultSpec("batch_build", 0, 10 ** 9),))
    with faults.inject(plan), pytest.raises(InjectedFault):
        next(iter(asyn))


# ---------------------------------------------------------------------------
# checkpoint integrity: CRC verification + restore_latest fallback
# ---------------------------------------------------------------------------
def _tree(s):
    return {"w": jnp.arange(12.0).reshape(3, 4) * (s + 1),
            "b": jnp.full((5,), s, jnp.int32)}


def test_restore_rejects_bit_rot():
    """A single flipped byte in a leaf file fails the CRC check."""
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, _tree(1))
        leaf = os.path.join(d, "step_000000001", "leaf_0.npy")
        with open(leaf, "r+b") as f:
            f.seek(-1, os.SEEK_END)
            byte = f.read(1)
            f.seek(-1, os.SEEK_END)
            f.write(bytes([byte[0] ^ 0xFF]))
        with pytest.raises(ckpt.CheckpointCorrupt, match="checksum"):
            ckpt.restore(d, 1, _tree(1))


def test_restore_latest_falls_back_past_corrupt(tiny_graph):
    """Newest checkpoint corrupt -> restore_latest lands on the next
    valid one, invoking on_corrupt per skip; all corrupt -> (None,)*3."""
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3):
            ckpt.save(d, s, _tree(s), extra={"s": s})
        rng = np.random.default_rng(0)
        skipped = []
        corrupt_checkpoint(os.path.join(d, "step_000000003"), rng,
                           mode="truncate", target="manifest.json")
        step, tree, extra = ckpt.restore_latest(
            d, _tree(0), on_corrupt=lambda s, e: skipped.append(s))
        assert (step, extra["s"]) == (2, 2)
        assert _leaves_equal(tree, _tree(2))
        assert skipped == [3]
        corrupt_checkpoint(os.path.join(d, "step_000000002"), rng,
                           mode="flip", target="leaf_1.npy")
        step, tree, extra = ckpt.restore_latest(d, _tree(0))
        assert (step, extra["s"]) == (1, 1)
        for s in (1,):
            corrupt_checkpoint(os.path.join(d, f"step_{s:09d}"), rng,
                               mode="truncate", target="leaf_0.npy")
        assert ckpt.restore_latest(d, _tree(0)) == (None, None, None)


def test_restore_rejects_leaf_count_mismatch():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, _tree(1))
        with pytest.raises(ckpt.CheckpointCorrupt, match="leaf count"):
            ckpt.restore(d, 1, {"only": jnp.zeros(3)})


def test_latest_step_and_gc_ignore_litter():
    """`.tmp_save_*` crash litter and malformed step_* names neither
    break latest_step/_gc nor survive the next save's sweep."""
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, _tree(1))
        os.makedirs(os.path.join(d, ".tmp_save_dead"))
        with open(os.path.join(d, ".tmp_save_dead", "leaf_0.npy"),
                  "wb") as f:
            f.write(b"partial")
        os.makedirs(os.path.join(d, "step_garbage"))
        assert ckpt.latest_step(d) == 1
        ckpt.save(d, 2, _tree(2), keep=2)       # _gc sweeps the litter
        assert not [x for x in os.listdir(d)
                    if x.startswith(".tmp_save_")]
        assert os.path.isdir(os.path.join(d, "step_garbage"))  # ignored
        assert ckpt.latest_step(d) == 2


# ---------------------------------------------------------------------------
# caps-cache robustness (satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("payload", [
    b"{ not json", b"\xff\xfe garbage \x00", b"[1, 2, 3]", b""])
def test_caps_calibrator_survives_corrupt_cache(tiny_graph, payload):
    """A corrupt caps-cache JSON is a cache miss, not a crash: discard,
    recalibrate, and the rewrite leaves a valid cache behind."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "caps.json")
        with open(path, "wb") as f:
            f.write(payload)
        cal = CapsCalibrator(cache_path=path, n_probe=2, seed=0)
        caps = cal.caps_for(tiny_graph, make_policy("rand"), BATCH, FANOUTS)
        assert len(caps) == len(FANOUTS) and all(c > 0 for c in caps)
        with open(path) as f:
            assert isinstance(json.load(f), dict)   # healthy again
        # warm read-back returns the same caps without reprobing
        assert cal.caps_for(tiny_graph, make_policy("rand"), BATCH,
                            FANOUTS) == caps


def test_caps_calibrator_survives_corrupt_entry(tiny_graph):
    """Valid JSON whose ENTRY is garbage (wrong arity, non-ints) falls
    through to a reprobe instead of returning nonsense caps."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "caps.json")
        cal = CapsCalibrator(cache_path=path, n_probe=2, seed=0)
        pol = make_policy("rand")
        caps = cal.caps_for(tiny_graph, pol, BATCH, FANOUTS)
        key = cal.key(tiny_graph, pol, BATCH, FANOUTS)
        for bad in (["x", "y"], [1], [0, -5], "nope"):
            with open(path, "w") as f:
                json.dump({key: bad}, f)
            assert cal.caps_for(tiny_graph, pol, BATCH, FANOUTS) == caps


# ---------------------------------------------------------------------------
# dynamic-cache integrity check (degradation trigger)
# ---------------------------------------------------------------------------
def test_cache_integrity_check_detects_corruption(tiny_graph):
    state = featcache.as_cache("dynamic:degree_hot", tiny_graph,
                               policy=make_policy("rand"),
                               batch_size=BATCH, fanouts=FANOUTS, seed=0)
    assert fdyn.integrity_ok(state)
    bad = fdyn._corrupt_state(state, np.random.default_rng(0))
    assert not fdyn.integrity_ok(bad)
    # a refill of a healthy state stays healthy
    feats = jnp.asarray(tiny_graph.features)
    new_state, _ = fdyn.refill(state, feats)
    assert fdyn.integrity_ok(new_state)
