"""Per-arch smoke tests (reduced configs, CPU): one train step + serve
equivalence (prefill/decode vs full forward). Covers all 10 assigned archs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, TrainConfig, long_context_ok
from repro.configs.registry import LM_ARCHS, get_config
from repro.launch.specs import (materialize, prefill_batch_specs,
                                train_batch_specs)
from repro.models.lm import transformer
from repro.optim import adamw
from repro.train.train_step import make_train_step

TCFG = TrainConfig(remat=True)


def _reduced(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe:
        cfg = cfg.scaled(capacity_factor=8.0)   # no drops in tiny tests
    return cfg


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_runs_and_is_finite(arch):
    import numpy as np
    cfg = _reduced(arch)
    params = transformer.init(cfg, jax.random.key(0), max_seq=64)
    before = jax.tree.map(np.asarray, params)   # host copy (params donated)
    batch = materialize(train_batch_specs(cfg, 2, 32))
    step, _ = make_train_step(cfg, TCFG)
    p2, o2, m = step(params, adamw.init(params), batch)
    assert bool(jnp.isfinite(m["loss"]))
    # params actually changed
    d = jax.tree.map(lambda a, b: float(np.abs(a - np.asarray(b)).max()),
                     before, p2)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_and_decode_match_forward(arch):
    cfg = _reduced(arch)
    T = 12
    params = transformer.init(cfg, jax.random.key(0), max_seq=64)
    batch = materialize(prefill_batch_specs(cfg, 2, T))
    batch["tokens"] = jax.random.randint(jax.random.key(5), (2, T), 0,
                                         cfg.vocab_size, jnp.int32)
    if "positions" in batch:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(T), (2, 3, T)).astype(jnp.int32)
    hidden, _ = transformer.apply(cfg, params, batch, remat=False)
    full_logits = transformer.unembed(cfg, params, hidden)

    pf_logits, _ = transformer.prefill(cfg, params, batch)
    assert float(jnp.max(jnp.abs(pf_logits[:, 0] - full_logits[:, -1]))) \
        < 1e-3

    cache = transformer.init_cache(cfg, 2, T, jnp.float32)
    if cfg.encoder_decoder:
        cache = transformer.prefill_cross(cfg, params, batch["frames"],
                                          cache)
    errs = []
    for t in range(T):
        kw = {}
        if cfg.mrope:
            kw["positions"] = jnp.full((2, 3, 1), t)
        if cfg.vision_tokens and t < cfg.vision_tokens:
            kw["embeds"] = batch["vision_embeds"][:, t:t + 1]
        lg, cache = transformer.decode_step(
            cfg, params, cache, batch["tokens"][:, t:t + 1], t, **kw)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, t]))))
    # one bf16 ulp at |logit|~4 is 0.0156; the hybrid arch sums two
    # normalized branches, so allow 2 ulps there
    tol = 4e-2 if cfg.hybrid else 1e-2
    assert max(errs) < tol, max(errs)


def test_long_context_skip_policy():
    """long_500k runs iff the arch is sub-quadratic (DESIGN.md §5)."""
    runs = {a for a in LM_ARCHS if long_context_ok(get_config(a))}
    assert runs == {"gemma3-27b", "gemma3-1b", "rwkv6-7b", "hymba-1.5b"}
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}


def test_loss_decreases_when_training():
    cfg = _reduced("gemma3-1b")
    params = transformer.init(cfg, jax.random.key(0), max_seq=64)
    opt = adamw.init(params)
    step, _ = make_train_step(cfg, TrainConfig(learning_rate=5e-3,
                                               remat=False))
    batch = materialize(train_batch_specs(cfg, 4, 32))
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9
