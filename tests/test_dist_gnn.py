"""repro.dist.gnn: community-sharded data-parallel GNN training.

The determinism headline (in-process, 1-replica mesh over the default
CPU device): sharded training is BIT-identical to single-device — exact
`==` on the 20-step loss trajectory and sha1-equal params. The 4-replica
behavior (convergence, per-replica streams concatenating to the exact
single-device epoch order, halo mirror == shard_map device path, Pallas
kernels under shard_map) runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4, per the conftest
contract that the main process sees ONE device.

Property tests (hypothesis; the `_hypothesis_stub` when the real package
is absent) pin the partition algebra on random community graphs: the
shard-position map is a bijection onto distinct padded slots, and the
host halo mirror reconstructs every cross-shard feature row exactly at
the dropless budget (r_cap = K, halo = D // 2).
"""
import hashlib
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import GNNConfig, TrainConfig
from repro.core import halo
from repro.dist import gnn as dist_gnn
from repro.train.gnn_loop import GNNTrainer


def _digest(tree) -> str:
    h = hashlib.sha1()
    for leaf in jax.tree.leaves(tree):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _cfg(graph, dropout=0.5):
    return GNNConfig(name="t", model="sage", num_layers=2, hidden_dim=16,
                     in_dim=graph.feat_dim, num_classes=graph.num_classes,
                     fanout=(5, 5), dropout=dropout)


def _tcfg(**kw):
    kw.setdefault("batch_size", 32)
    kw.setdefault("max_epochs", 2)
    return TrainConfig(seed=0, **kw)


# ---------------------------------------------------------------------------
# 1-replica mesh == single device, bit for bit (in-process: the default
# CPU device IS a valid 1-device mesh)
# ---------------------------------------------------------------------------
def test_one_replica_bit_identical_to_single_device(tiny_graph):
    cfg, tcfg = _cfg(tiny_graph), _tcfg()
    single = GNNTrainer(tiny_graph, cfg, tcfg, "comm_rand", seed=3)
    losses_s = single.train_steps(20)

    mesh = dist_gnn.make_gnn_mesh(1)
    sharded = GNNTrainer(tiny_graph, cfg, tcfg, "comm_rand", seed=3,
                         mesh=mesh)
    losses_m = sharded.train_steps(20)

    assert losses_s == losses_m          # exact ==, not allclose
    assert _digest(single.params) == _digest(sharded.params)
    assert _digest(single.opt_state) == _digest(sharded.opt_state)


def test_one_replica_plan_is_identity(tiny_graph):
    plan = dist_gnn.community_shard_plan(tiny_graph, 1)
    n = tiny_graph.num_nodes
    assert plan.n_per_shard == n and plan.n_padded == n
    np.testing.assert_array_equal(plan.shard_pos, np.arange(n))
    np.testing.assert_array_equal(plan.perm, np.arange(n))
    hp = dist_gnn.plan_halo(plan, tiny_graph, (5, 5), 128)
    assert hp.mode == "halo" and hp.halo == 0


def test_sharded_checkpoint_resume_bit_exact(tiny_graph, tmp_path):
    cfg, tcfg = _cfg(tiny_graph), _tcfg()
    mesh = dist_gnn.make_gnn_mesh(1)
    a = GNNTrainer(tiny_graph, cfg, tcfg, "comm_rand", seed=3, mesh=mesh,
                   ckpt_dir=str(tmp_path / "ck"), ckpt_every=5)
    losses_a = a.train_steps(10)
    # resume from the step-10 checkpoint: the continuation must replay
    # the uninterrupted run exactly (cursor + replicated-on-mesh state)
    b = GNNTrainer(tiny_graph, cfg, tcfg, "comm_rand", seed=3, mesh=mesh,
                   ckpt_dir=str(tmp_path / "ck"))
    assert b.global_step == 10
    c = GNNTrainer(tiny_graph, cfg, tcfg, "comm_rand", seed=3, mesh=mesh)
    losses_c = c.train_steps(10)
    assert losses_c == losses_a
    assert c.train_steps(5) == b.train_steps(5)
    assert _digest(b.params) == _digest(c.params)


def test_mesh_rejects_unsupported_modes(tiny_graph):
    mesh = dist_gnn.make_gnn_mesh(1)
    with pytest.raises(ValueError, match="pipeline"):
        GNNTrainer(tiny_graph, _cfg(tiny_graph), _tcfg(), "comm_rand",
                   mesh=mesh, pipeline="async")
    with pytest.raises(ValueError, match="dynamic"):
        GNNTrainer(tiny_graph, _cfg(tiny_graph), _tcfg(), "comm_rand",
                   mesh=mesh, cache="dynamic:degree_hot")
    # batch divisibility is checked against the mesh size; with a
    # 1-replica mesh any size divides, so assert via the stream directly
    plan2 = dist_gnn.ShardPlan(2, 4, 2, np.arange(4, dtype=np.int32),
                               np.arange(4, dtype=np.int64),
                               np.zeros(1, np.int32))
    with pytest.raises(ValueError, match="divisible"):
        dist_gnn.ShardedBatchStream(
            tiny_graph, "comm_rand", 33, (5, 5), (64, 128),
            mesh=mesh, plan=plan2)


# ---------------------------------------------------------------------------
# partition + halo-plan algebra (host-side, no mesh required)
# ---------------------------------------------------------------------------
def _random_community_graph(rng, n, n_comm, feat_dim=4):
    """A tiny CSR graph with contiguous community blocks (what
    `core.reorder.prepare` guarantees) and random intra/inter edges."""
    from repro.graphs.csr import Graph
    bounds = np.sort(rng.choice(np.arange(1, n), n_comm - 1,
                                replace=False)) if n_comm > 1 else []
    comm = np.zeros(n, np.int32)
    for b in bounds:
        comm[b:] += 1
    adj = [set() for _ in range(n)]
    for _ in range(n * 3):
        u, v = int(rng.integers(n)), int(rng.integers(n))
        if u != v:
            adj[u].add(v)
            adj[v].add(u)
    indptr = np.zeros(n + 1, np.int64)
    indices = []
    for u in range(n):
        nbrs = sorted(adj[u])
        indices.extend(nbrs)
        indptr[u + 1] = len(indices)
    ids = np.arange(n)
    return Graph(indptr=indptr, indices=np.asarray(indices, np.int32),
                 features=rng.normal(size=(n, feat_dim)).astype(np.float32),
                 labels=rng.integers(0, 3, n).astype(np.int32),
                 train_ids=ids, val_ids=ids[:2], test_ids=ids[:2],
                 communities=comm.astype(np.int32), name="prop")


@settings(max_examples=10)
@given(n=st.integers(8, 60), n_comm=st.integers(1, 6),
       n_shards=st.integers(1, 5), seed=st.integers(0, 10 ** 6))
def test_shard_pos_is_a_bijection(n, n_comm, n_shards, seed):
    rng = np.random.default_rng(seed)
    g = _random_community_graph(rng, n, min(n_comm, n))
    plan = dist_gnn.community_shard_plan(g, n_shards)
    # injective onto distinct padded slots...
    assert len(np.unique(plan.shard_pos)) == n
    assert plan.shard_pos.min() >= 0
    assert plan.shard_pos.max() < plan.n_padded
    # ...and perm inverts it exactly; every non-slot is the -1 sentinel
    np.testing.assert_array_equal(plan.perm[plan.shard_pos], np.arange(n))
    assert (plan.perm >= 0).sum() == n
    # communities are never split across shards
    owner = plan.shard_of_node
    comm = np.asarray(g.communities)
    for c in np.unique(comm):
        assert len(np.unique(owner[comm == c])) == 1


@settings(max_examples=10)
@given(n=st.integers(8, 48), n_comm=st.integers(1, 5),
       n_shards=st.integers(2, 4), k=st.integers(3, 16),
       seed=st.integers(0, 10 ** 6))
def test_halo_roundtrip_reconstructs_cross_shard_rows(n, n_comm, n_shards,
                                                      k, seed):
    """community partition -> halo exchange -> every requested feature
    row (cross-shard included) is reconstructed EXACTLY at the dropless
    budget; sentinel ids come back as zero rows."""
    rng = np.random.default_rng(seed)
    g = _random_community_graph(rng, n, min(n_comm, n))
    plan = dist_gnn.community_shard_plan(g, n_shards)
    d, ns = plan.n_shards, plan.n_per_shard
    local = np.zeros((plan.n_padded, g.feat_dim), np.float32)
    valid = plan.perm >= 0
    local[valid] = g.features[plan.perm[valid]]

    ids = rng.integers(0, n + 3, size=(d, k))          # n.. are sentinels
    rid = np.where(ids < n, plan.shard_pos[np.minimum(ids, n - 1)],
                   plan.n_padded)
    out, dropped = halo.halo_gather_np(
        local.reshape(d, ns, g.feat_dim), rid,
        n_per_shard=ns, r_cap=k, halo=d // 2)
    assert int(dropped.sum()) == 0
    want = np.where((ids < n)[..., None],
                    g.features[np.minimum(ids, n - 1)], 0.0)
    np.testing.assert_array_equal(out, want)


@settings(max_examples=6)
@given(n_shards=st.integers(2, 5), seed=st.integers(0, 10 ** 6))
def test_plan_halo_budget_covers_reachability(n_shards, seed):
    rng = np.random.default_rng(seed)
    g = _random_community_graph(rng, 40, 5)
    plan = dist_gnn.community_shard_plan(g, n_shards)
    hp = dist_gnn.plan_halo(plan, g, (5, 5), 64, mode="halo")
    assert hp.mode == "halo"
    assert 0 <= hp.halo <= n_shards // 2      # ring distance cap
    assert hp.r_cap == 64
    # restricting roots to one replica's communities can only shrink it
    rb = np.tile(np.arange(n_shards * 4) % g.num_nodes,
                 (2, 1)).astype(np.int64)
    hp_rooted = dist_gnn.plan_halo(plan, g, (5, 5), 64,
                                   root_batches=rb, mode="halo")
    assert hp_rooted.halo <= hp.halo


def test_plan_halo_auto_falls_back_to_global(tiny_graph):
    """mode="auto" degrades to the all-gather fallback exactly when the
    forced ring plan's modeled bytes exceed the global gather's."""
    plan = dist_gnn.community_shard_plan(tiny_graph, 4)
    forced = dist_gnn.plan_halo(plan, tiny_graph, (5, 5), 1024,
                                mode="halo")
    auto = dist_gnn.plan_halo(plan, tiny_graph, (5, 5), 1024)
    hb = forced.bytes_per_gather(1024, tiny_graph.feat_dim, 4)
    gb = dist_gnn.HaloPlan("global", 0, 0).bytes_per_gather(
        1024, tiny_graph.feat_dim, 4)
    if hb > gb:
        assert auto == dist_gnn.HaloPlan("global", 0, 0)
    else:                                   # cheap ring: halo stands
        assert auto == forced
    # explicit mode="global" always wins
    forced_g = dist_gnn.plan_halo(plan, tiny_graph, (5, 5), 1024,
                                  mode="global")
    assert forced_g.mode == "global"


# ---------------------------------------------------------------------------
# 4-replica mesh (subprocess: conftest pins the main process to 1 device)
# ---------------------------------------------------------------------------
FOUR_REPLICA_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np, jax.numpy as jnp
jax.config.update("jax_platform_name", "cpu")
assert jax.device_count() == 4
from jax.sharding import PartitionSpec as P
from repro.core import halo
from repro.core.reorder import prepare
from repro.graphs import synthetic
from repro.configs.base import GNNConfig, TrainConfig
from repro.train.gnn_loop import GNNTrainer
from repro.dist import gnn as dist_gnn
from repro.dist.sharding import shard_map

g = prepare(synthetic.load("tiny"), oracle=True)
cfg = GNNConfig(name="t", model="sage", num_layers=2, hidden_dim=16,
                in_dim=g.feat_dim, num_classes=g.num_classes,
                fanout=(5, 5), dropout=0.5)
tcfg = TrainConfig(batch_size=32, max_epochs=3, seed=0)
mesh = dist_gnn.make_gnn_mesh(4)
tr = GNNTrainer(g, cfg, tcfg, "comm_rand", seed=3, mesh=mesh)

# per-replica root slices concatenate to the EXACT single-device order
single = GNNTrainer(g, cfg, tcfg, "comm_rand", seed=3)
for epoch in (0, 1):
    rb = tr.stream.replica_root_batches(epoch)
    assert rb.shape[1] == 4
    np.testing.assert_array_equal(
        rb.reshape(rb.shape[0], -1), single.stream.root_batches(epoch))
print("CONCAT_OK")

losses = tr.train_steps(40)
assert np.isfinite(losses).all()
assert losses[-1] < losses[0], (losses[0], losses[-1])
ev = tr.evaluate(g.val_ids)
assert np.isfinite(ev["loss"]) and 0.0 <= ev["acc"] <= 1.0
print("CONVERGE_OK")

# forced halo-mode plan trains too (dropless: r_cap = cap_L)
tr2 = GNNTrainer(g, cfg, tcfg, "comm_rand", seed=3, mesh=mesh)
tr2._hplan = dist_gnn.HaloPlan("halo", 2, tr2.caps[-1])
tr2._hplan_epoch = 0
l2 = tr2.train_steps(8)
assert np.isfinite(l2).all()
print("HALO_MODE_OK")

# host mirror == device exchange, element for element
D, Ns, F, K = 4, 8, 5, 12
rng = np.random.default_rng(0)
feats = rng.normal(size=(D, Ns, F)).astype(np.float32)
ids = rng.integers(0, Ns * D + 6, size=(D, K))
def f(fl, il):
    out, drop = halo.halo_gather(fl[0], il[0], n_per_shard=Ns, r_cap=K,
                                 halo=D // 2, axis="shard")
    return out[None], drop[None]
m = jax.jit(shard_map(f, mesh, (P("shard"), P("shard")),
                      (P("shard"), P("shard"))))
out_dev, drop_dev = m(jnp.asarray(feats), jnp.asarray(ids))
out_np, drop_np = halo.halo_gather_np(feats, ids, n_per_shard=Ns,
                                      r_cap=K, halo=D // 2)
assert np.array_equal(np.asarray(out_dev), out_np)
assert np.array_equal(np.asarray(drop_dev), drop_np)
print("MIRROR_OK")

# the fused Pallas kernels run under shard_map (interpret mode on CPU)
from repro.kernels.gather_agg.ops import gather_agg
x = jnp.asarray(rng.normal(size=(4, 16, 8)).astype(np.float32))
idx = jnp.asarray(rng.integers(0, 16, size=(4, 6, 3)), jnp.int32)
w = jnp.ones((4, 6, 3), jnp.float32)
def agg(x, idx, w):
    return gather_agg(x[0], idx[0], w[0], impl="pallas")[None]
out = jax.jit(shard_map(agg, mesh, (P("shard"), P("shard"), P("shard")),
                        P("shard")))(x, idx, w)
ref = np.stack([np.asarray(gather_agg(x[i], idx[i], w[i], impl="jnp"))
                for i in range(4)])
np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)
print("KERNELS_OK")
"""


def _run_sub(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)


def test_four_replica_mesh_subprocess():
    out = _run_sub(FOUR_REPLICA_SCRIPT)
    for marker in ("CONCAT_OK", "CONVERGE_OK", "HALO_MODE_OK",
                   "MIRROR_OK", "KERNELS_OK"):
        assert marker in out.stdout, (marker, out.stdout, out.stderr[-3000:])
