"""Static-shape batch builder: dedup exactness, index validity, policy
footprint ordering (the paper's Fig 6 mechanism)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import BASELINE_POLICY, BEST_POLICY, CommRandPolicy
from repro.core import minibatch as mb, partition
from repro.graphs.csr import DeviceGraph


@pytest.fixture(scope="module")
def gdev(tiny_graph):
    return DeviceGraph.from_graph(tiny_graph)


def _build(tiny_graph, gdev, roots, fanouts=(5, 5), caps=(1024, 1536),
           p=0.5, key=0):
    labels = jnp.asarray(tiny_graph.labels)
    return mb.build_batch(jax.random.key(key), gdev,
                          jnp.asarray(roots, jnp.int32), labels,
                          fanouts, caps, p)


def test_levels_are_sorted_unique_supersets(tiny_graph, gdev):
    roots = tiny_graph.train_ids[:128]
    b = _build(tiny_graph, gdev, roots)
    N = tiny_graph.num_nodes
    prev = None
    for lvl in b.levels:
        arr = np.asarray(lvl)
        real = arr[arr < N]
        assert (np.diff(arr) >= 0).all()
        assert len(np.unique(real)) == len(real)
        if prev is not None:
            assert set(prev) <= set(real)
        prev = real


def test_block_positions_consistent(tiny_graph, gdev):
    roots = tiny_graph.train_ids[:128]
    b = _build(tiny_graph, gdev, roots)
    L = len(b.blocks)
    for i, blk in enumerate(b.blocks):
        src_level = np.asarray(b.levels[L - i])
        dst_level = np.asarray(b.levels[L - i - 1])
        sp = np.asarray(blk.self_pos)
        ok = np.asarray(blk.dst_mask)
        assert (src_level[sp[ok]] == dst_level[ok]).all()
        em = np.asarray(blk.edge_mask)
        srcs = src_level[np.asarray(blk.src_pos)]
        assert (srcs[em] < tiny_graph.num_nodes).all()


def test_labels_align_with_roots(tiny_graph, gdev):
    roots = tiny_graph.train_ids[:64]
    b = _build(tiny_graph, gdev, np.pad(roots, (0, 64), constant_values=-1))
    lm = np.asarray(b.label_mask)
    lv = np.asarray(b.levels[0])
    lab = np.asarray(b.labels)
    assert lm.sum() == 64
    assert (lab[lm] == tiny_graph.labels[lv[lm]]).all()


def test_footprint_ordering_across_policies(tiny_graph, gdev):
    """Unique input nodes: RAND p=.5 > COMM-RAND p=1 > NORAND p=1 (Fig 6)."""
    rng = np.random.default_rng(0)
    sizes = {}
    for name, pol in [("rand", BASELINE_POLICY),
                      ("best", BEST_POLICY),
                      ("norand", CommRandPolicy("norand", 0.0, 1.0))]:
        batches = partition.batches_for_epoch(
            tiny_graph.train_ids, tiny_graph.communities, pol, 256, rng)
        caps = (2048, 2048)
        tot = []
        for k, b in enumerate(batches[:4]):
            bb = _build(tiny_graph, gdev, b, caps=caps, p=pol.p, key=k)
            tot.append(int(bb.num_unique))
        sizes[name] = np.mean(tot)
    assert sizes["norand"] <= sizes["best"] < sizes["rand"]


def test_capacity_overflow_degrades_gracefully(tiny_graph, gdev):
    roots = tiny_graph.train_ids[:256]
    tight = _build(tiny_graph, gdev, roots, caps=(320, 384))
    assert int(tight.num_unique) <= 384
    for blk in tight.blocks:
        assert np.asarray(blk.edge_mask).dtype == np.bool_


def test_calibrated_caps_hold(tiny_graph, gdev):
    pol = BEST_POLICY
    caps = mb.calibrate_caps(tiny_graph, pol, 128, (5, 5), n_probe=4)
    rng = np.random.default_rng(3)
    batches = partition.batches_for_epoch(
        tiny_graph.train_ids, tiny_graph.communities, pol, 128, rng)
    b = _build(tiny_graph, gdev, batches[0], caps=caps, p=pol.p)
    N = tiny_graph.num_nodes
    # no silent drops: every sampled edge lands
    for blk in b.blocks:
        em = np.asarray(blk.edge_mask)
        dm = np.asarray(blk.dst_mask)
        assert em[dm].any(axis=1).mean() > 0.99
