"""The `repro.sampling` subsystem: registry round-trips, the LABOR
shared-randomness invariants, footprint ordering vs rand, back-compat of
the legacy `core.sampler` / float-p entry points, and the satellite
refactors that rode along (vectorized reorder, bucketed ClusterGCN)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sampling
from repro.batching import BatchStream, available_policies, make_policy
from repro.batching.policy import root_batches
from repro.core import minibatch as mb
from repro.graphs.csr import DeviceGraph

FANOUTS = (5, 5)


@pytest.fixture(scope="module")
def gdev(tiny_graph):
    return DeviceGraph.from_graph(tiny_graph)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_has_all_samplers():
    assert set(sampling.available_samplers()) >= {"biased", "uniform",
                                                  "full", "labor"}


@pytest.mark.parametrize("name", ["biased", "uniform", "full", "labor"])
def test_registry_roundtrip(name, gdev, tiny_graph):
    s = sampling.make_sampler(name)
    assert s.name == name
    assert s.describe()
    assert sampling.as_sampler(name).describe() == s.describe()
    assert sampling.as_sampler(s) is s
    assert sampling.as_sampler((name, {})).describe() == s.describe()
    nodes = jnp.asarray(tiny_graph.train_ids[:32], jnp.int32)
    srcs, mask = s.sample(jax.random.key(0), gdev, nodes, 7)
    assert srcs.shape == (32, 7) and mask.shape == (32, 7)
    # picks are real neighbors (or self)
    g = tiny_graph
    srcs_np, mask_np = np.asarray(srcs), np.asarray(mask)
    for i, u in enumerate(np.asarray(nodes)):
        nbrs = set(g.indices[g.indptr[u]:g.indptr[u + 1]].tolist())
        for j in range(7):
            if mask_np[i, j]:
                assert int(srcs_np[i, j]) in nbrs or int(srcs_np[i, j]) == u


def test_unknown_sampler_raises():
    with pytest.raises(KeyError):
        sampling.make_sampler("nope")
    with pytest.raises(TypeError):
        sampling.as_sampler(object())


def test_every_policy_binds_a_sampler():
    for name in available_policies():
        s = sampling.for_policy(make_policy(name))
        assert hasattr(s, "sample")
    assert sampling.for_policy(make_policy("labor")).name == "labor"
    assert sampling.for_policy(make_policy("comm_rand", p=1.0)).p == 1.0


# ---------------------------------------------------------------------------
# back-compat shims
# ---------------------------------------------------------------------------
def test_core_sampler_shim_is_bit_exact(gdev, tiny_graph):
    from repro.core.sampler import sample_neighbors
    nodes = jnp.asarray(tiny_graph.train_ids[:64], jnp.int32)
    for p in (0.5, 0.9):
        with pytest.deprecated_call():
            s_old, m_old = sample_neighbors(jax.random.key(3), gdev, nodes,
                                            9, p)
        s_new, m_new = sampling.BiasedTwoPhaseSampler(p).sample(
            jax.random.key(3), gdev, nodes, 9)
        np.testing.assert_array_equal(np.asarray(s_old), np.asarray(s_new))
        np.testing.assert_array_equal(np.asarray(m_old), np.asarray(m_new))
    with pytest.deprecated_call():
        s_old, m_old = sample_neighbors(jax.random.key(4), gdev, nodes, 9,
                                        0.5, mode="all")
    s_new, m_new = sampling.FullNeighborhoodSampler().sample(
        jax.random.key(4), gdev, nodes, 9)
    np.testing.assert_array_equal(np.asarray(s_old), np.asarray(s_new))
    np.testing.assert_array_equal(np.asarray(m_old), np.asarray(m_new))


def test_build_batch_float_p_equals_sampler_object(gdev, tiny_graph):
    """The legacy float-p signature routes through BiasedTwoPhaseSampler."""
    roots = jnp.asarray(tiny_graph.train_ids[:128], jnp.int32)
    labels = jnp.asarray(tiny_graph.labels)
    a = mb.build_batch(jax.random.key(1), gdev, roots, labels, FANOUTS,
                       (1024, 1536), 0.9)
    b = mb.build_batch(jax.random.key(1), gdev, roots, labels, FANOUTS,
                       (1024, 1536), sampling.BiasedTwoPhaseSampler(0.9))
    for la, lb in zip(a.levels, b.levels):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))


# ---------------------------------------------------------------------------
# LABOR shared-randomness invariants
# ---------------------------------------------------------------------------
def _picks(srcs, mask, row):
    return set(np.asarray(srcs)[row][np.asarray(mask)[row]].tolist())


def test_labor_same_source_same_picks_within_epoch(gdev, tiny_graph):
    """The same source node draws the same neighbors wherever it appears
    (any row, any node set, any hop) under one epoch key — and fresh ones
    under the next epoch's key."""
    lab = sampling.LaborSampler()
    k = jax.random.key(11)
    us = [int(u) for u in tiny_graph.train_ids[:8]]
    a, am = lab.sample(k, gdev, jnp.asarray(us, jnp.int32), 5)
    b, bm = lab.sample(k, gdev, jnp.asarray(us[::-1] + [0, 1], jnp.int32), 5)
    for i, u in enumerate(us):
        assert _picks(a, am, i) == _picks(b, bm, len(us) - 1 - i)
    k2 = jax.random.key(12)
    c, cm = lab.sample(k2, gdev, jnp.asarray(us, jnp.int32), 5)
    assert any(_picks(a, am, i) != _picks(c, cm, i)
               for i in range(len(us)))


def test_labor_picks_without_replacement(gdev, tiny_graph):
    lab = sampling.LaborSampler()
    nodes = jnp.asarray(tiny_graph.train_ids[:64], jnp.int32)
    srcs, mask = lab.sample(jax.random.key(2), gdev, nodes, 8)
    srcs, mask = np.asarray(srcs), np.asarray(mask)
    deg = tiny_graph.degrees()[np.asarray(nodes)]
    for i in range(64):
        got = srcs[i][mask[i]]
        assert len(np.unique(got)) == len(got)      # no duplicates
        assert mask[i].sum() == min(deg[i], 8)


def test_labor_footprint_below_rand_and_matches_numpy_estimator(tiny_graph):
    """Fig-6-style footprint: device LABOR strictly below rand at equal
    fanout, and consistent with the `labor_lite_epoch_footprint` numpy
    estimator (same shared-rank top-k semantics, different rank source)."""
    from repro.train.baselines import labor_lite_epoch_footprint

    def device_mean(pol_name, n=5):
        st = BatchStream(tiny_graph, make_policy(pol_name), 256, FANOUTS,
                         (2048, 2048), seed=0, dispatch_ahead=False)
        sizes = []
        for i, b in enumerate(st.epoch()):
            sizes.append(int(b.num_unique))
            if i + 1 >= n:
                break
        return float(np.mean(sizes))

    uniq_rand = device_mean("rand")
    uniq_labor = device_mean("labor")
    assert uniq_labor < uniq_rand
    est = labor_lite_epoch_footprint(
        tiny_graph, root_batches(tiny_graph, "labor", 256, seed=0)[:5],
        FANOUTS)
    assert 0.85 < uniq_labor / est < 1.18, (uniq_labor, est)


def test_labor_trains_through_jit_pipeline(tiny_graph):
    """make_policy("labor") must train through the compiled device path
    with a finite, decreasing loss."""
    from repro.configs.base import GNNConfig, TrainConfig
    from repro.train.gnn_loop import GNNTrainer
    g = tiny_graph
    cfg = GNNConfig("t", "sage", 2, 32, g.feat_dim, g.num_classes,
                    fanout=FANOUTS)
    tr = GNNTrainer(g, cfg, TrainConfig(batch_size=256, max_epochs=2),
                    make_policy("labor"), caps=(1536, 1792),
                    eval_caps=(1536, 2048), seed=0)
    assert tr.sampler.name == "labor"
    losses = tr.train_steps(8)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_labor_caps_calibrate_below_rand(tiny_graph):
    """Cap calibration keys on the bound sampler: LABOR's input-level cap
    must come out at or below rand's."""
    caps_rand = mb.calibrate_caps(tiny_graph, make_policy("rand"), 256,
                                  FANOUTS, n_probe=4)
    caps_labor = mb.calibrate_caps(tiny_graph, make_policy("labor"), 256,
                                   FANOUTS, n_probe=4)
    assert caps_labor[-1] <= caps_rand[-1]


def test_calibrator_cache_key_covers_sampler(tiny_graph):
    from repro.batching import CapsCalibrator
    cal = CapsCalibrator()
    k_rand = cal.key(tiny_graph, make_policy("rand"), 256, FANOUTS)
    k_labor = cal.key(tiny_graph, make_policy("labor"), 256, FANOUTS)
    assert k_rand != k_labor
    assert "labor" in k_labor


# ---------------------------------------------------------------------------
# full-neighborhood sampler (mode="all" retirement)
# ---------------------------------------------------------------------------
def test_full_sampler_enumerates_all_neighbors(gdev, tiny_graph):
    g = tiny_graph
    u = int(g.train_ids[0])
    deg = int(g.degrees()[u])
    srcs, mask = sampling.FullNeighborhoodSampler().sample(
        jax.random.key(0), gdev, jnp.asarray([u], jnp.int32), deg + 4)
    got = set(np.asarray(srcs)[0][np.asarray(mask)[0]].tolist())
    assert got == set(g.indices[g.indptr[u]:g.indptr[u + 1]].tolist())
    assert int(np.asarray(mask).sum()) == deg


# ---------------------------------------------------------------------------
# satellites: vectorized reorder + bucketed ClusterGCN
# ---------------------------------------------------------------------------
def test_reorder_vectorized_matches_loop_reference(tiny_graph):
    from repro.graphs.csr import reorder
    g = tiny_graph
    rng = np.random.default_rng(9)
    perm = rng.permutation(g.num_nodes)
    out = reorder(g, perm)
    # per-node loop reference (the old implementation)
    perm_inv = np.empty(g.num_nodes, np.int64)
    perm_inv[perm] = np.arange(g.num_nodes)
    ref = np.empty_like(g.indices)
    new_indptr = np.zeros(g.num_nodes + 1, np.int64)
    np.cumsum(g.degrees()[perm], out=new_indptr[1:])
    for i in range(g.num_nodes):
        s, e = g.indptr[perm[i]], g.indptr[perm[i] + 1]
        ref[new_indptr[i]:new_indptr[i + 1]] = perm_inv[g.indices[s:e]]
    np.testing.assert_array_equal(out.indptr, new_indptr)
    np.testing.assert_array_equal(out.indices, ref)
    np.testing.assert_array_equal(out.features, g.features[perm])


def test_clustergcn_bucketed_groups_match_isin_reference(tiny_graph):
    from repro.batching.policy import ClusterGCNPolicy
    g = tiny_graph
    pol = ClusterGCNPolicy(parts_per_batch=3)
    # member_groups vs the old O(C*N) np.isin implementation
    got = pol.member_groups(g.communities, np.random.default_rng(4))
    want = [np.where(np.isin(g.communities, u))[0]
            for u in pol.community_order(g.communities,
                                         np.random.default_rng(4))]
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)
    # epoch_order vs the old membership-mask implementation
    got_o = pol.epoch_order(g.train_ids, g.communities,
                            np.random.default_rng(5))
    member = np.zeros(int(g.communities.max()) + 1, bool)
    parts = []
    for u in pol.community_order(g.communities, np.random.default_rng(5)):
        member[:] = False
        member[u] = True
        parts.append(g.train_ids[member[g.communities[g.train_ids]]])
    np.testing.assert_array_equal(got_o, np.concatenate(parts))
