"""Sharding rules: every assigned arch's param tree gets valid, divisible
specs on the production mesh (subprocess with fake devices)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from jax.sharding import NamedSharding
from repro.configs.registry import LM_ARCHS, get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.lm import transformer

for multi_pod in (False, True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    for arch in LM_ARCHS:
        cfg = get_config(arch)
        aparams = transformer.abstract_params(cfg)
        specs = shd.param_specs(aparams, mesh)
        def check(sds, spec):
            for dim, ax in zip(sds.shape, spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= shape[a]
                assert dim % n == 0, (arch, sds.shape, spec)
        jax.tree.map(check, aparams, specs)
        # embedding is TP-sharded (vocab padding did its job)
        emb_spec = specs["embed"]
        assert emb_spec[0] is not None, (arch, "embed not sharded")
print("SHARDING_OK")
"""


def test_param_specs_divisible_all_archs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDING_OK" in out.stdout, out.stderr[-3000:]


def test_vocab_padding():
    from repro.configs.registry import get_config
    assert get_config("whisper-large-v3").padded_vocab % 256 == 0
    assert get_config("hymba-1.5b").padded_vocab % 256 == 0
    assert get_config("gemma3-27b").padded_vocab == 262144  # already aligned


# ---------------------------------------------------------------------------
# GNN side (repro.dist.gnn): the community-sharded artifacts carry the
# layouts the data-parallel trainer relies on
# ---------------------------------------------------------------------------
def test_gnn_feature_and_state_shardings(tiny_graph):
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist import gnn as dist_gnn
    from repro.models.gnn.models import init_gnn
    from repro.configs.base import GNNConfig

    mesh = dist_gnn.make_gnn_mesh(1)
    plan = dist_gnn.community_shard_plan(tiny_graph, 1)
    feats = plan.shard_features(tiny_graph.features, mesh)
    assert feats.sharding == NamedSharding(mesh, P("shard", None))
    # 1-shard layout is the identity: rows are bit-copies in id order
    np.testing.assert_array_equal(np.asarray(feats),
                                  np.asarray(tiny_graph.features))
    pos = plan.device_pos(mesh)
    assert pos.sharding.is_fully_replicated

    cfg = GNNConfig("t", "sage", 2, 16, tiny_graph.feat_dim,
                    tiny_graph.num_classes, fanout=(5, 5))
    params = init_gnn(cfg, jax.random.key(0))
    rep = dist_gnn.replicate(params, mesh)
    for leaf in jax.tree.leaves(rep):
        assert leaf.sharding.is_fully_replicated
    # state_shardings mirrors the tree with replicated NamedShardings
    # (what sharded checkpoint restore device_puts with)
    shards = dist_gnn.state_shardings(params, mesh)
    assert jax.tree.structure(shards) == jax.tree.structure(params)
    for s in jax.tree.leaves(
            shards, is_leaf=lambda x: isinstance(x, NamedSharding)):
        assert s == NamedSharding(mesh, P())


def test_sharded_batch_stream_layout(tiny_graph):
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist import gnn as dist_gnn

    mesh = dist_gnn.make_gnn_mesh(1)
    plan = dist_gnn.community_shard_plan(tiny_graph, 1)
    stream = dist_gnn.ShardedBatchStream(
        tiny_graph, "comm_rand", 32, (5, 5), (512, 1024), seed=3,
        mesh=mesh, plan=plan)
    batch = stream.build(stream.root_batches(0)[0], 0, 0)
    sh = NamedSharding(mesh, P("shard"))
    for leaf in jax.tree.leaves(batch):
        assert leaf.shape[0] == 1            # leading shard axis
        assert leaf.sharding == sh
    # the single replica's sub-batch ids equal the single-device build's
    from repro.batching.stream import BatchStream
    base = BatchStream(tiny_graph, "comm_rand", 32, (5, 5), (512, 1024),
                       seed=3)
    ref = base.build(base.root_batches(0)[0], 0, 0)
    np.testing.assert_array_equal(np.asarray(batch.node_mask[0]),
                                  np.asarray(ref.node_mask))
    np.testing.assert_array_equal(np.asarray(batch.labels[0]),
                                  np.asarray(ref.labels))


def test_gnn_mesh_too_many_shards_raises():
    import pytest

    from repro.dist import gnn as dist_gnn

    with pytest.raises(RuntimeError, match="devices"):
        dist_gnn.make_gnn_mesh(4096)
