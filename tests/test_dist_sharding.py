"""Sharding rules: every assigned arch's param tree gets valid, divisible
specs on the production mesh (subprocess with fake devices)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from jax.sharding import NamedSharding
from repro.configs.registry import LM_ARCHS, get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.lm import transformer

for multi_pod in (False, True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    for arch in LM_ARCHS:
        cfg = get_config(arch)
        aparams = transformer.abstract_params(cfg)
        specs = shd.param_specs(aparams, mesh)
        def check(sds, spec):
            for dim, ax in zip(sds.shape, spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= shape[a]
                assert dim % n == 0, (arch, sds.shape, spec)
        jax.tree.map(check, aparams, specs)
        # embedding is TP-sharded (vocab padding did its job)
        emb_spec = specs["embed"]
        assert emb_spec[0] is not None, (arch, "embed not sharded")
print("SHARDING_OK")
"""


def test_param_specs_divisible_all_archs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDING_OK" in out.stdout, out.stderr[-3000:]


def test_vocab_padding():
    from repro.configs.registry import get_config
    assert get_config("whisper-large-v3").padded_vocab % 256 == 0
    assert get_config("hymba-1.5b").padded_vocab % 256 == 0
    assert get_config("gemma3-27b").padded_vocab == 262144  # already aligned
