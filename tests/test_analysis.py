"""repro.analysis: lint rules (positive/negative/waiver per rule), the
waiver grammar, strict gating on the real tree, and the jaxpr contract
auditor against the real train step + fused builder (interpret mode)."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.config import AnalysisConfig
from repro.analysis.lint import LintReport, lint_paths, lint_source, \
    parse_waivers
from repro.analysis.rules import RULES

SRC = Path(__file__).resolve().parent.parent / "src"


def rules_hit(source, relpath, config=None):
    return {v.rule for v in lint_source(source, relpath, config)
            if not v.waived}


# ---------------------------------------------------------------------------
# per-rule fixtures: positive + negative + waiver
# ---------------------------------------------------------------------------
class TestNoGlobalNumpyRandom:
    def test_positive_seed_and_module_fns(self):
        src = ("import numpy as np\n"
               "np.random.seed(0)\n"
               "x = np.random.rand(3)\n")
        vs = [v for v in lint_source(src, "repro/core/foo.py")
              if v.rule == "no-global-numpy-random"]
        assert {v.line for v in vs} == {2, 3}

    def test_negative_generator_constructors(self):
        src = ("import numpy as np\n"
               "rng = np.random.default_rng((1, 2))\n"
               "ss = np.random.SeedSequence(7)\n")
        assert "no-global-numpy-random" not in rules_hit(
            src, "repro/core/foo.py")

    def test_waiver(self):
        src = ("import numpy as np\n"
               "np.random.seed(0)  # analysis: allow[no-global-numpy-random] -- fixture\n")
        (v,) = [v for v in lint_source(src, "repro/core/foo.py")
                if v.rule == "no-global-numpy-random"]
        assert v.waived and v.justification == "fixture"


class TestNoStdlibRandom:
    def test_positive(self):
        assert "no-stdlib-random" in rules_hit(
            "import random\n", "repro/core/foo.py")
        assert "no-stdlib-random" in rules_hit(
            "from random import shuffle\n", "repro/core/foo.py")

    def test_negative(self):
        assert "no-stdlib-random" not in rules_hit(
            "import numpy as np\n", "repro/core/foo.py")

    def test_waiver(self):
        src = ("# analysis: allow[no-stdlib-random] -- fixture only\n"
               "import random\n")
        (v,) = lint_source(src, "repro/core/foo.py")
        assert v.waived


class TestNoWallClock:
    SRC = "import time\nt = time.time()\nm = time.monotonic()\n"

    def test_positive_in_deterministic_module(self):
        vs = [v for v in lint_source(self.SRC, "repro/pipeline/foo.py")
              if v.rule == "no-wall-clock"]
        assert {v.line for v in vs} == {2, 3}

    def test_datetime_now(self):
        src = "from datetime import datetime\nd = datetime.now()\n"
        assert "no-wall-clock" in rules_hit(src, "repro/batching/foo.py")

    def test_negative_outside_deterministic_scope(self):
        # wall clock is FINE in the trainer/bench layer
        assert "no-wall-clock" not in rules_hit(self.SRC,
                                                "repro/train/foo.py")

    def test_waiver(self):
        src = ("import time\n"
               "t = time.monotonic()  # analysis: allow[no-wall-clock] -- heartbeat\n")
        (v,) = [v for v in lint_source(src, "repro/pipeline/foo.py")
                if v.rule == "no-wall-clock"]
        assert v.waived and v.justification == "heartbeat"


HOT_CFG = AnalysisConfig(
    hot_functions={"repro/pipeline/hot.py": ("hot_fn",),
                   "repro/kernels/k.py": ("*",)})


class TestNoHostSyncInHotPath:
    def test_positive_float_item_asarray(self):
        src = ("import numpy as np\n"
               "def hot_fn(x):\n"
               "    a = float(x)\n"
               "    b = x.item()\n"
               "    c = np.asarray(x)\n"
               "    return a, b, c\n")
        vs = [v for v in lint_source(src, "repro/pipeline/hot.py", HOT_CFG)
              if v.rule == "no-host-sync-in-hot-path"]
        assert {v.line for v in vs} == {3, 4, 5}

    def test_negative_outside_hot_function(self):
        src = ("def cold_fn(x):\n"
               "    return float(x)\n")
        assert not rules_hit(src, "repro/pipeline/hot.py", HOT_CFG)

    def test_negative_literal_argument(self):
        # float('inf') etc: constant folding, not a device sync
        src = ("def hot_fn(x):\n"
               "    return float('inf')\n")
        assert not rules_hit(src, "repro/pipeline/hot.py", HOT_CFG)

    def test_star_marks_whole_module(self):
        src = ("import jax\n"
               "def anything(x):\n"
               "    return jax.device_get(x)\n")
        assert "no-host-sync-in-hot-path" in rules_hit(
            src, "repro/kernels/k.py", HOT_CFG)

    def test_waiver(self):
        src = ("def hot_fn(x):\n"
               "    # analysis: allow[no-host-sync-in-hot-path] -- boundary flush\n"
               "    return float(x)\n")
        (v,) = lint_source(src, "repro/pipeline/hot.py", HOT_CFG)
        assert v.waived and v.justification == "boundary flush"


class TestNoF64InDeviceCode:
    def test_positive(self):
        src = ("import jax.numpy as jnp\n"
               "x = jnp.zeros(3, jnp.float64)\n"
               "y = x.astype('float64')\n")
        vs = [v for v in lint_source(src, "repro/kernels/foo.py")
              if v.rule == "no-f64-in-device-code"]
        assert {v.line for v in vs} == {2, 3}

    def test_negative_host_exempt_module(self):
        # featcache/plan.py computes f64 scores on host and casts at the
        # device boundary — exempt via config
        src = "import numpy as np\ns = np.float64(1.0)\n"
        assert not rules_hit(src, "repro/featcache/plan.py")

    def test_negative_non_device_module(self):
        src = "import numpy as np\ns = np.float64(1.0)\n"
        assert not rules_hit(src, "repro/core/community.py")


class TestRngStructuredSeed:
    def test_positive_bare_int_and_entropy(self):
        src = ("import numpy as np\n"
               "a = np.random.default_rng(5)\n"
               "b = np.random.default_rng()\n")
        vs = [v for v in lint_source(src, "repro/core/foo.py")
              if v.rule == "rng-structured-seed"]
        assert {v.line for v in vs} == {2, 3}

    def test_negative_tuple_seed(self):
        src = ("import numpy as np\n"
               "a = np.random.default_rng((5, 0))\n"
               "b = np.random.default_rng((seed, epoch, pos))\n")
        assert "rng-structured-seed" not in rules_hit(src,
                                                      "repro/core/foo.py")


class TestNoDeprecatedImport:
    def test_positive(self):
        assert "no-deprecated-import" in rules_hit(
            "from repro.core.cachesim import lru_misses\n",
            "repro/featcache/foo.py")
        assert "no-deprecated-import" in rules_hit(
            "import repro.core.sampler\n", "repro/sampling/foo.py")
        assert "no-deprecated-import" in rules_hit(
            "from repro.core import cachesim\n", "repro/featcache/foo.py")

    def test_negative_replacement_and_shim_itself(self):
        assert "no-deprecated-import" not in rules_hit(
            "from repro.featcache import sim\n", "repro/featcache/foo.py")
        # the shim module re-exporting is not a violation of itself
        assert "no-deprecated-import" not in rules_hit(
            "from repro.featcache.sim import *\n",
            "repro/core/cachesim.py")


# ---------------------------------------------------------------------------
# waiver grammar + strict gating
# ---------------------------------------------------------------------------
class TestWaivers:
    def test_parse_same_line_and_line_above(self):
        src = ("x = 1  # analysis: allow[rule-a] -- because reasons\n"
               "# analysis: allow[rule-b] -- next line covered\n"
               "y = 2\n")
        w = parse_waivers(src)
        assert w[(1, "rule-a")] == "because reasons"
        assert w[(3, "rule-b")] == "next line covered"

    def test_unjustified_waiver_fails_strict(self):
        src = ("import random  # analysis: allow[no-stdlib-random]\n")
        vs = lint_source(src, "repro/core/foo.py")
        rep = LintReport(violations=vs, files_checked=1)
        assert vs[0].waived and not rep.strict_ok()

    def test_wrong_rule_name_does_not_waive(self):
        src = ("import random  # analysis: allow[no-wall-clock] -- wrong\n")
        (v,) = lint_source(src, "repro/core/foo.py")
        assert not v.waived


def test_repo_is_strict_clean():
    """The acceptance gate: zero unwaived violations across src/repro
    and every waiver names a known rule and carries a justification."""
    report = lint_paths(SRC)
    assert report.files_checked > 90
    msgs = [f"{v.path}:{v.line} [{v.rule}] {v.message}"
            for v in report.unwaived]
    assert not msgs, "\n".join(msgs)
    assert not report.unjustified()
    assert not report.unknown_waivers
    # the audited waivers documented in the PR are present
    waived_files = {v.path for v in report.waived}
    assert "repro/pipeline/prefetch.py" in waived_files
    assert "repro/train/gnn_loop.py" in waived_files


def test_no_internal_deprecated_importers():
    """Satellite: no src/repro module imports the deprecation shims."""
    report = lint_paths(SRC)
    dep = [v for v in report.violations
           if v.rule == "no-deprecated-import"]
    assert dep == []


def test_rule_registry_complete():
    assert set(RULES) == {
        "no-global-numpy-random", "no-stdlib-random", "no-wall-clock",
        "no-host-sync-in-hot-path", "no-f64-in-device-code",
        "rng-structured-seed", "no-deprecated-import"}


# ---------------------------------------------------------------------------
# jaxpr contract auditor
# ---------------------------------------------------------------------------
from repro.analysis import jaxpr_audit as ja  # noqa: E402


def test_donation_effective():
    assert ja.audit_donation()["ok"]


def test_kernels_pallas_contract():
    rep = ja.audit_kernels()
    for name in ("gather_agg_fwd", "gather_agg_bwd",
                 "gather_cached_fwd", "gather_cached_bwd"):
        r = rep[name]
        assert r["pallas_calls"] >= 1, (name, r)
        assert r["callbacks"] == 0 and r["f64_casts"] == 0, (name, r)
        assert r["feature_gathers"] == 0, (name, r)
    assert rep["ok"]


def test_feature_gather_detector_flags_reference_impl():
    """The detector must actually fire on the materialized fallback —
    the jnp reference path gathers feature-shaped rows."""
    from repro.kernels.gather_agg.ops import gather_agg
    x = jnp.ones((64, 32), jnp.float32)
    idx = jnp.zeros((16, 4), jnp.int32)
    w = jnp.ones((16, 4), jnp.float32)
    closed = jax.make_jaxpr(
        lambda x, idx, w: gather_agg(x, idx, w, impl="jnp"))(x, idx, w)
    assert len(ja.feature_gathers(closed, 32)) >= 1


def test_device_order_audit(tiny_graph):
    rep = ja.audit_device_order(tiny_graph)
    for pol in ("rand", "norand", "comm_rand", "clustergcn", "labor"):
        assert rep[pol]["stable"], (pol, rep[pol])
        assert rep[pol]["ok"], (pol, rep[pol])
    assert rep["ok"]


def test_fused_build_audit(tiny_graph):
    """Jaxpr hash identical across (pos, epoch, resume) for all five
    policies: the fused builder never retraces within a run."""
    rep = ja.audit_fused_build(tiny_graph)
    for pol in ("rand", "norand", "comm_rand", "clustergcn", "labor"):
        r = rep[pol]
        assert r["stable"] and r["callbacks"] == 0 and \
            r["f64_casts"] == 0 and r["f64_avals"] == 0, (pol, r)
    assert rep["ok"]


def test_train_step_audit(tiny_graph):
    """The guarded train step: callback-free, f64-free, hash-stable
    across poison/lr/key/batch/resume, Pallas path declared -> present."""
    rep = ja.audit_train_step(tiny_graph)
    assert rep["callbacks"] == 0
    assert rep["f64_casts"] == 0 and rep["f64_avals"] == 0
    assert rep["stable"], rep
    assert rep["pallas"]["pallas_calls"] >= 1
    assert rep["eval"]["ok"]
    assert rep["ok"]


def test_recompile_guard_catches_tracer_constant():
    """Pinned regression: a weak-typed python scalar CAPTURED in the
    closure embeds as a jaxpr literal — the hash must drift (that is the
    silent-retrace bug class). The same scalar passed as an ARGUMENT
    must not."""
    x = jnp.ones((4,), jnp.float32)

    def make_step(scale):
        def step(x):
            return x * scale        # captured: becomes a literal
        return step

    h_captured = [ja.make_hash(make_step(s), x) for s in (1.5, 2.5)]
    assert h_captured[0] != h_captured[1]

    def step_arg(x, scale):
        return x * scale            # argument: traced, value-free

    h_arg = [ja.make_hash(step_arg, x, s) for s in (1.5, 2.5)]
    assert h_arg[0] == h_arg[1]
    # and the poison scalar in the real step rides as an argument: the
    # full train-step audit above proves nan vs 1.0 never retraces


def test_callback_detector_fires():
    """The callback check is not vacuous: a deliberate pure_callback is
    found through the pjit wrapper."""
    import numpy as np

    @jax.jit
    def bad(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct(x.shape, x.dtype),
            x)

    closed = jax.make_jaxpr(bad)(jnp.ones(3))
    assert ja.callback_eqns(closed)


def test_f64_detector_fires():
    # x64 must be on for a true f64 cast to exist at all (the default
    # config truncates to f32 — itself part of the no-f64 posture); the
    # context keeps the widening strictly inside this test
    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(
            lambda x: x.astype("float64"))(jnp.ones(3, jnp.float32))
    assert ja.f64_casts(closed) or ja.f64_avals(closed)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_lint_only(tmp_path):
    out = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict",
         "--skip-jaxpr", "--json", str(out)],
        capture_output=True, text=True, cwd=str(SRC.parent),
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu",
             "HOME": str(tmp_path)})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rep = json.loads(out.read_text())
    assert rep["strict_ok"]
    assert rep["lint"]["files_checked"] > 90
    assert rep["lint"]["n_violations"] == 0
    assert rep["lint"]["n_waived"] > 0
    # every waiver in the report carries its justification
    for rule, entry in rep["lint"]["rules"].items():
        for w in entry["waivers"]:
            assert w["justification"], (rule, w)


def test_sharded_step_audit(tiny_graph):
    """The data-parallel (shard_map) step under the same contract:
    callback-free, f64-free, grads psum-reduced, donation aliased in the
    lowering, ONE jaxpr hash across poison/lr/key/batch/fresh-trainer."""
    rep = ja.audit_sharded_step(tiny_graph)
    assert rep["callbacks"] == 0
    assert rep["f64_casts"] == 0 and rep["f64_avals"] == 0
    assert rep["stable"], rep
    assert rep["spmd"] and rep["n_devices"] == 1
    assert rep["psums"] >= 1            # grads + loss + mask count
    assert rep["halo_plan"]["mode"] in ("halo", "global")
    assert rep["halo_plan"]["halo"] == 0      # 1-device ring
    assert rep["donation_aliased"]
    assert rep["ok"]
