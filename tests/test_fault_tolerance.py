"""Checkpoint/restart, failure injection, straggler detection, gradient
compression, elastic resharding (subprocess with 8 fake devices)."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import LMStream, SyntheticTokens
from repro.optim.compression import (compress_decompress, compressed_bytes,
                                     init_error_feedback)
from repro.train import checkpoint as ckpt
from repro.train.lm_loop import LMTrainer
from repro.train.monitor import StragglerMonitor, resilient_step


def _trainer(tmp, **tk):
    cfg = get_config("gemma3-1b").reduced()
    tcfg = TrainConfig(learning_rate=3e-3, remat=False, **tk)
    corpus = SyntheticTokens(cfg.vocab_size, num_docs=128, doc_len=64)
    return LMTrainer(cfg, tcfg, LMStream(corpus, batch=4, seq=32),
                     ckpt_dir=tmp, ckpt_every=4)


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(10.0), "b": [jnp.ones((3, 3)),
                                             jnp.zeros(2, jnp.int32)]}
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, tree, extra={"s": s}, keep=2)
        assert ckpt.latest_step(d) == 5
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(steps) == 2
        got, extra = ckpt.restore(d, 5, tree)
        assert extra == {"s": 5}
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_is_exact():
    """Train 8 steps straight vs 4 + crash + resume + 4: same loss curve."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        a = _trainer(d1)
        ra = a.run(8)
        b = _trainer(d2)
        b.run(4)
        del b
        b2 = _trainer(d2)      # resumes from step 4
        assert b2.step == 4
        rb = b2.run(4)
        np.testing.assert_allclose(ra["losses"][4:], rb["losses"],
                                   rtol=1e-5)


def test_failure_injection_recovers():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d)
        calls = {"n": 0}

        def hook(step):
            if step == 2 and calls["n"] < 2:
                calls["n"] += 1
                raise RuntimeError("injected")

        r = tr.run(4, fail_hook=hook)
        assert calls["n"] == 2
        assert np.isfinite(r["loss_last"])


def test_resilient_step_gives_up_and_calls_hook():
    state = {"gave_up": False}

    def always_fails():
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        resilient_step(always_fails, max_retries=1,
                       on_give_up=lambda: state.update(gave_up=True))
    assert state["gave_up"]


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=2)
    for i in range(10):
        m.observe(0.1, i)
    assert m.observe(0.5, 11) is True
    assert m.straggler_fraction > 0
    # slow steps must NOT poison the EMA
    assert m.ema < 0.15


@settings(max_examples=15, deadline=None)
@given(shape=st.sampled_from([(64,), (31,), (8, 9), (256,)]),
       scale=st.floats(1e-3, 1e3), seed=st.integers(0, 50))
def test_compression_error_bound(shape, scale, seed):
    g = {"w": jax.random.normal(jax.random.key(seed), shape) * scale}
    err = init_error_feedback(g)
    deq, err2 = compress_decompress(g, err)
    # blockwise int8: |err| <= scale_of_block/2 <= max|g|/254 * 2
    bound = float(jnp.abs(g["w"]).max()) / 127.0
    assert float(jnp.abs(err2["w"]).max()) <= bound + 1e-6


def test_error_feedback_preserves_mean_signal():
    """EF: sum over steps of dequantized ~= sum of true gradients."""
    key = jax.random.key(0)
    g_true = jax.random.normal(key, (128,))
    err = init_error_feedback({"w": g_true})
    acc = jnp.zeros_like(g_true)
    for i in range(50):
        deq, err = compress_decompress({"w": g_true}, err)
        acc = acc + deq["w"]
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true),
                               atol=2e-3)


def test_compressed_bytes_is_4x_smaller():
    g = {"w": jnp.zeros((1024, 1024))}
    assert compressed_bytes(g) < 1024 * 1024 * 4 / 3.8


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile, jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs.registry import get_config
from repro.dist import sharding as shd
from repro.models.lm import transformer
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.lm_loop import elastic_reshard

cfg = get_config("gemma3-1b").reduced()
params = transformer.init(cfg, jax.random.key(0), max_seq=64)
opt = adamw.init(params)
mesh_a = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("data", "model"))
mesh_b = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
pa = shd.param_shardings(params, mesh_a)
params_a = jax.tree.map(jax.device_put, params, pa)
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 7, {"params": params_a, "opt": opt})
    step, tree, _ = ckpt.restore_latest(d, {"params": params, "opt": opt})
    assert step == 7
    state_b = elastic_reshard(tree, mesh_b)
    # every leaf now lives on mesh_b with valid shardings
    leaf = jax.tree.leaves(state_b["params"])[0]
    assert len(leaf.sharding.device_set) <= 4
    # values survive the reshard
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)
print("ELASTIC_OK")
"""


def test_elastic_reshard_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# property-based checkpoint corruption (repro.resilience payloads)
# ---------------------------------------------------------------------------
def _ckpt_tree(s):
    return {"w": jnp.arange(24.0).reshape(4, 6) * (s + 1),
            "c": jnp.full((7,), s, jnp.int32)}


@settings(max_examples=8, deadline=None)
@given(n_corrupt=st.integers(0, 2),
       mode=st.sampled_from(["truncate", "flip"]),
       seed=st.integers(0, 10 ** 6))
def test_restore_latest_lands_on_newest_valid(n_corrupt, mode, seed):
    """Property: damage the newest `n_corrupt` of 3 checkpoints with a
    random payload (truncate a random file to a prefix, or flip one byte
    of the manifest or a leaf) — `restore_latest` lands on the newest
    UNCORRUPTED step with every leaf value intact, and reports exactly
    the skipped steps to `on_corrupt`."""
    from repro.resilience import corrupt_checkpoint

    rng = np.random.default_rng(seed)
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3):
            ckpt.save(d, s, _ckpt_tree(s), extra={"s": s}, keep=3)
        for s in (3, 2)[:n_corrupt]:
            corrupt_checkpoint(os.path.join(d, f"step_{s:09d}"), rng,
                               mode=mode)
        skipped = []
        step, tree, extra = ckpt.restore_latest(
            d, _ckpt_tree(0), on_corrupt=lambda s, e: skipped.append(s))
        want = 3 - n_corrupt
        assert step == want and extra["s"] == want
        assert skipped == list(range(3, want, -1))
        for a, b in zip(jax.tree.leaves(_ckpt_tree(want)),
                        jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=6, deadline=None)
@given(target=st.sampled_from(["manifest.json", "leaf_0.npy",
                               "leaf_1.npy"]),
       mode=st.sampled_from(["truncate", "flip"]),
       seed=st.integers(0, 10 ** 6))
def test_any_single_file_corruption_is_detected(target, mode, seed):
    """Property: damaging ANY one checkpoint file — manifest or either
    leaf, torn or bit-rotted — makes `restore` raise CheckpointCorrupt
    rather than return silently wrong state."""
    from repro.resilience import corrupt_checkpoint

    rng = np.random.default_rng(seed)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, _ckpt_tree(2), extra={"s": 2})
        corrupt_checkpoint(os.path.join(d, "step_000000005"), rng,
                           mode=mode, target=target)
        with pytest.raises(ckpt.CheckpointCorrupt):
            ckpt.restore(d, 5, _ckpt_tree(0))
