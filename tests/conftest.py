import sys

import jax
import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see ONE device.
# Multi-device tests spawn subprocesses with their own flags.

jax.config.update("jax_platform_name", "cpu")

try:
    import hypothesis  # noqa: F401 — the real package wins when installed
except ModuleNotFoundError:
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


@pytest.fixture(scope="session")
def tiny_graph():
    from repro.core.reorder import prepare
    from repro.graphs import synthetic
    return prepare(synthetic.load("tiny"), oracle=True)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
