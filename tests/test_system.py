"""End-to-end behaviour: the paper's core claims on a small synthetic
community graph (orderings, not absolute numbers — DESIGN.md §7)."""
import numpy as np
import pytest

from repro.configs.base import (BASELINE_POLICY, BEST_POLICY,
                                CommRandPolicy, GNNConfig, TrainConfig)
from repro.core.reorder import prepare
from repro.graphs import synthetic
from repro.train.gnn_loop import train_once


@pytest.fixture(scope="module")
def setup():
    g = prepare(synthetic.load("tiny"), oracle=True)
    cfg = GNNConfig("sage-sys", "sage", 2, 32, g.feat_dim, g.num_classes,
                    fanout=(5, 5))
    tcfg = TrainConfig(batch_size=256, max_epochs=12, early_stop_patience=4)
    return g, cfg, tcfg


@pytest.fixture(scope="module")
def results(setup):
    g, cfg, tcfg = setup
    out = {}
    for name, pol in [("rand", BASELINE_POLICY), ("best", BEST_POLICY),
                      ("norand", CommRandPolicy("norand", 0.0, 1.0))]:
        out[name] = train_once(g, cfg, pol, tcfg, seed=0)
    return out


def test_commrand_shrinks_working_set(results):
    """Paper Fig 6 mechanism: community bias -> fewer unique input nodes."""
    assert results["best"].mean_unique_nodes < \
        0.7 * results["rand"].mean_unique_nodes
    assert results["norand"].mean_unique_nodes <= \
        results["best"].mean_unique_nodes * 1.05


def test_commrand_accuracy_within_tolerance(results):
    """Paper: COMM-RAND within ~1.8pp of the uniform-random baseline
    (small-graph tolerance is looser)."""
    assert results["best"].val_acc >= results["rand"].val_acc - 0.06


def test_model_actually_learns(results):
    for r in results.values():
        assert r.val_acc > 0.5     # >> 1/num_classes (0.25)


def test_calibrated_caps_order(results):
    assert results["best"].caps[-1] <= results["rand"].caps[-1]


def test_training_produces_history(results):
    r = results["rand"]
    assert len(r.history) >= 3
    assert r.per_epoch_time_s > 0
    assert r.total_time_s >= r.per_epoch_time_s * len(r.history) * 0.5
