"""GNN correctness: sampled-tower forward equals a dense reference when the
fanout covers every neighbor (mode='all')."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core import minibatch as mb
from repro.graphs.csr import DeviceGraph
from repro.models.gnn.models import apply_gnn, init_gnn


def _dense_sage_ref(graph, params, roots):
    """numpy full-neighborhood SAGE (mean aggregator, relu between)."""
    x = graph.features.astype(np.float64)
    L = len(params["layers"])
    h = x
    for li, p in enumerate(params["layers"]):
        nxt = np.zeros((graph.num_nodes, p["w_self"].shape[1]))
        for u in range(graph.num_nodes):
            nbr = graph.indices[graph.indptr[u]:graph.indptr[u + 1]]
            mean = h[nbr].mean(axis=0) if len(nbr) else h[u]
            nxt[u] = h[u] @ np.asarray(p["w_self"], np.float64) + \
                mean @ np.asarray(p["w_neigh"], np.float64) + \
                np.asarray(p["b"], np.float64)
        h = np.maximum(nxt, 0) if li < L - 1 else nxt
    return h[roots]


@pytest.fixture(scope="module")
def small_setup(tiny_graph):
    g = tiny_graph
    gdev = DeviceGraph.from_graph(g)
    cfg = GNNConfig("t", "sage", 2, 16, g.feat_dim, g.num_classes,
                    fanout=(64, 64), dropout=0.0)
    params = init_gnn(cfg, jax.random.key(0))
    return g, gdev, cfg, params


def test_sage_full_neighborhood_matches_dense(small_setup):
    g, gdev, cfg, params = small_setup
    max_deg = int(g.degrees().max())
    roots = g.train_ids[:32]
    caps = (g.num_nodes + 128, g.num_nodes + 128)
    batch = mb.build_batch(jax.random.key(0), gdev,
                           jnp.asarray(roots, jnp.int32),
                           jnp.asarray(g.labels),
                           (max_deg, max_deg), caps, 0.5, mode="all")
    feats = jnp.asarray(g.features)
    x = feats[jnp.minimum(batch.node_ids, g.num_nodes - 1)]
    logits = apply_gnn(cfg, params, batch, x, gdev.degrees)
    lv = np.asarray(batch.levels[0])
    lm = np.asarray(batch.label_mask)
    ref = _dense_sage_ref(g, params, lv[lm])
    got = np.asarray(logits)[lm]
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("model", ["sage", "gcn", "gat"])
def test_models_forward_finite(small_setup, model, tiny_graph):
    g, gdev, _, _ = small_setup
    cfg = GNNConfig("t", model, 3, 32, g.feat_dim, g.num_classes,
                    fanout=(5, 5, 5))
    params = init_gnn(cfg, jax.random.key(1))
    batch = mb.build_batch(jax.random.key(2), gdev,
                           jnp.asarray(g.train_ids[:64], jnp.int32),
                           jnp.asarray(g.labels), (5, 5, 5),
                           (512, 1024, 1536), 0.9)
    feats = jnp.asarray(g.features)
    x = feats[jnp.minimum(batch.node_ids, g.num_nodes - 1)]
    logits = apply_gnn(cfg, params, batch, x, gdev.degrees)
    assert logits.shape == (64, g.num_classes)
    assert bool(jnp.isfinite(logits).all())


def test_gnn_gradients_flow(small_setup):
    g, gdev, cfg, params = small_setup
    batch = mb.build_batch(jax.random.key(3), gdev,
                           jnp.asarray(g.train_ids[:32], jnp.int32),
                           jnp.asarray(g.labels), (4, 4), (512, 768), 1.0)
    feats = jnp.asarray(g.features)

    def loss(p):
        x = feats[jnp.minimum(batch.node_ids, g.num_nodes - 1)]
        lg = apply_gnn(cfg, p, batch, x, gdev.degrees)
        from repro.train.losses import gnn_softmax_ce
        return gnn_softmax_ce(lg, batch.labels,
                              batch.label_mask.astype(jnp.float32))

    grads = jax.grad(loss)(params)
    norms = [float(jnp.abs(x).sum()) for x in jax.tree.leaves(grads)]
    assert all(np.isfinite(norms)) and sum(norms) > 0


@pytest.mark.parametrize("model", ["sage", "gcn", "gat"])
def test_feats_global_matches_pregathered(small_setup, model, tiny_graph):
    """apply_gnn(..., feats_global=True) — layer 0 composing src_pos with
    batch.node_ids — must equal the legacy pre-gathered-x path."""
    g, gdev, _, _ = small_setup
    cfg = GNNConfig("t", model, 2, 32, g.feat_dim, g.num_classes,
                    fanout=(4, 4), dropout=0.0)
    params = init_gnn(cfg, jax.random.key(4))
    batch = mb.build_batch(jax.random.key(5), gdev,
                           jnp.asarray(g.train_ids[:32], jnp.int32),
                           jnp.asarray(g.labels), (4, 4), (256, 384), 0.9)
    feats = jnp.asarray(g.features)
    x = feats[jnp.minimum(batch.node_ids, g.num_nodes - 1)]
    legacy = apply_gnn(cfg, params, batch, x, gdev.degrees)
    glob = apply_gnn(cfg, params, batch, feats, gdev.degrees,
                     feats_global=True)
    np.testing.assert_allclose(np.asarray(glob), np.asarray(legacy),
                               rtol=1e-5, atol=1e-5)


def test_train_steps_loss_trajectory_matches_across_agg_impl(tiny_graph):
    """20 optimizer steps through the real trainer: the fused Pallas path
    (interpret mode here) must reproduce the jnp path's loss trajectory."""
    from repro.batching import make_policy
    from repro.configs.base import TrainConfig
    from repro.train.gnn_loop import GNNTrainer

    g = tiny_graph
    tcfg = TrainConfig(batch_size=128, max_epochs=2)
    pol = make_policy("comm_rand", mix=0.125, p=1.0)
    traj = {}
    for impl in ("jnp", "pallas"):
        cfg = GNNConfig("t", "sage", 2, 32, g.feat_dim, g.num_classes,
                        fanout=(4, 4), agg_impl=impl)
        traj[impl] = GNNTrainer(g, cfg, tcfg, pol, seed=0).train_steps(20)
    np.testing.assert_allclose(traj["pallas"], traj["jnp"],
                               rtol=1e-5, atol=1e-5)
