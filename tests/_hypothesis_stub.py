"""Minimal stand-in for `hypothesis` when the real package is absent.

The tier-1 suite property-tests with hypothesis, but the package is not part
of the runtime deps. When it is missing, `conftest.py` installs this stub
into `sys.modules`: `@given` draws `max_examples` deterministic samples per
strategy (seeded from the test name) and calls the test once per draw.
The real package, when installed, always wins.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

__version__ = "0.0-stub"


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def sample(self, rng):
        return self._draw(rng)


def _sampled_from(seq):
    items = list(seq)
    return _Strategy(lambda rng: items[int(rng.integers(len(items)))])


def _integers(lo, hi):
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def _floats(lo, hi):
    return _Strategy(lambda rng: float(rng.uniform(lo, hi)))


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


strategies = types.ModuleType("hypothesis.strategies")
strategies.sampled_from = _sampled_from
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 10)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)

        # hide the strategy-drawn params from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        return wrapper
    return deco
