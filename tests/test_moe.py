"""Sort-based MoE vs the dense per-expert oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.lm.moe import (init_moe, moe_capacity, moe_ffn,
                                 moe_group_count, moe_ref)


def _cfg(shared=False):
    base = "qwen2-moe-a2.7b" if shared else "qwen3-moe-235b-a22b"
    cfg = get_config(base).reduced()
    return cfg.scaled(capacity_factor=8.0)   # no drops


@pytest.mark.parametrize("shared", [False, True])
def test_moe_matches_dense_oracle(shared):
    cfg = _cfg(shared)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model))
    out, aux = moe_ffn(x, p, cfg)
    ref = moe_ref(x, p, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) >= 0


def test_capacity_drops_are_bounded():
    cfg = _cfg().scaled(capacity_factor=1.0)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model))
    out, _ = moe_ffn(x, p, cfg)
    ref = moe_ref(x, p, cfg)
    # with cf=1.0 some tokens drop: outputs differ but stay bounded
    assert bool(jnp.isfinite(out).all())
    rel = float(jnp.abs(out - ref).mean() / jnp.abs(ref).mean())
    assert rel < 1.0


def test_group_count_and_capacity():
    assert moe_group_count(4096 * 3) == 3
    assert moe_group_count(100) == 1
    cfg = _cfg()
    assert moe_capacity(4096, cfg) % 8 == 0


def test_moe_grads_flow_to_all_param_kinds():
    cfg = _cfg(shared=True)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (32, cfg.d_model))

    def loss(p):
        out, aux = moe_ffn(x, p, cfg)
        return (out ** 2).mean() + aux

    g = jax.grad(loss)(p)
    for name, leaf in g.items():
        assert float(jnp.abs(leaf).sum()) > 0, f"no grad into {name}"
