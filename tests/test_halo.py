"""Halo-gather correctness on REAL GNN artifacts.

In-process tests drive `halo_gather_np` — the host mirror one subprocess
test in tests/test_dist_gnn.py pins `==` the shard_map device path — on
the pinned `tiny` graph: a real community shard plan, real sampled batch
node ids from the real `BatchStream`, features reconstructed exactly at
the dropless budget, out-of-budget requests dropped-and-counted (never
wrong), and the h == D/2 ring-dedup regression. The subprocess test runs
the same real-artifact gather through `dist.gnn.gather_batch_features`
under `shard_map` on 4 fake devices (the conftest pins the main process
to ONE device) for both halo and global modes.
"""
import os
import subprocess
import sys

import numpy as np

from repro.batching.stream import BatchStream
from repro.core import halo
from repro.dist import gnn as dist_gnn


def _shard_feats(plan, graph):
    """(D, Ns, F) shard-local layout of the real feature matrix."""
    local = np.zeros((plan.n_padded, graph.feat_dim), np.float32)
    valid = plan.perm >= 0
    local[valid] = np.asarray(graph.features)[plan.perm[valid]]
    return local.reshape(plan.n_shards, plan.n_per_shard, graph.feat_dim)


def _batch_rids(plan, stream, epoch=0, pos=0):
    """Real sampled batch node ids for every replica, remapped to the
    padded slot space (sentinel -> n_padded). Returns (ids, rids)."""
    d = plan.n_shards
    rb = stream.root_batches(epoch)[pos]
    bs = len(rb) // d
    ids = []
    for r in range(d):
        b = stream.build(rb[r * bs:(r + 1) * bs], epoch, pos)
        ids.append(np.asarray(b.node_ids))
    ids = np.stack(ids)                                  # (D, K) global
    n = plan.n_nodes
    rids = np.where(ids < n, plan.shard_pos[np.minimum(ids, n - 1)],
                    plan.n_padded)
    return ids, rids


def test_real_batch_roundtrip_dropless(tiny_graph):
    """Real comm_rand batch ids through the halo exchange at the
    trainer's budget (r_cap = cap_L, halo = ring max): every valid row
    is the exact global feature row, sentinels are zero rows, nothing
    is dropped."""
    plan = dist_gnn.community_shard_plan(tiny_graph, 4)
    stream = BatchStream(tiny_graph, "comm_rand", 32, (5, 5), (512, 1024),
                         seed=3)
    ids, rids = _batch_rids(plan, stream)
    feats = _shard_feats(plan, tiny_graph)
    out, dropped = halo.halo_gather_np(
        feats, rids, n_per_shard=plan.n_per_shard, r_cap=ids.shape[1],
        halo=2)
    assert int(dropped.sum()) == 0
    n = plan.n_nodes
    want = np.where((ids < n)[..., None],
                    np.asarray(tiny_graph.features)[np.minimum(ids, n - 1)],
                    0.0)
    np.testing.assert_array_equal(out, want)


def test_out_of_budget_rows_drop_never_corrupt(tiny_graph):
    """Starved budget (tiny r_cap): dropped requests are COUNTED and
    their rows stay exactly zero — a served row is still exact. The
    budget failure mode is visible, never silent corruption."""
    plan = dist_gnn.community_shard_plan(tiny_graph, 4)
    stream = BatchStream(tiny_graph, "comm_rand", 32, (5, 5), (512, 1024),
                         seed=3)
    ids, rids = _batch_rids(plan, stream)
    feats = _shard_feats(plan, tiny_graph)
    out, dropped = halo.halo_gather_np(
        feats, rids, n_per_shard=plan.n_per_shard, r_cap=2, halo=1)
    assert int(dropped.sum()) > 0            # the starvation actually bites
    n = plan.n_nodes
    want = np.where((ids < n)[..., None],
                    np.asarray(tiny_graph.features)[np.minimum(ids, n - 1)],
                    0.0)
    d, k = ids.shape
    for r in range(d):
        for j in range(k):
            row = out[r, j]
            assert np.array_equal(row, want[r, j]) or \
                not row.any(), (r, j)


def test_half_ring_dedup_regression():
    """Pinned regression: at h == D/2 the +h and -h directions reach the
    SAME shard; visiting it twice doubled every row it served. Both the
    D=4/halo=2 and D=2/halo=1 rings must reconstruct exactly once."""
    rng = np.random.default_rng(7)
    for d in (2, 4):
        ns, f = 6, 3
        feats = rng.normal(size=(d, ns, f)).astype(np.float32)
        flat = feats.reshape(d * ns, f)
        # every request targets the diametrically opposite shard
        ids = np.stack([
            rng.integers(((r + d // 2) % d) * ns,
                         ((r + d // 2) % d + 1) * ns, 5)
            for r in range(d)])
        out, dropped = halo.halo_gather_np(
            feats, ids, n_per_shard=ns, r_cap=5, halo=d // 2)
        assert int(dropped.sum()) == 0
        np.testing.assert_array_equal(out, flat[ids])   # not 2 * flat[ids]


def test_collective_bytes_model_orders():
    """The napkin model the halo planner compares against: ring bytes
    grow with halo distance and are independent of D; the global
    fallback grows with D."""
    k, f = 1024, 64
    ring1 = halo.collective_bytes_model(k, f, 8, k, 1, "halo")
    ring2 = halo.collective_bytes_model(k, f, 8, k, 2, "halo")
    assert ring2 == 2 * ring1
    assert ring1 == halo.collective_bytes_model(k, f, 64, k, 1, "halo")
    g8 = halo.collective_bytes_model(k, f, 8, 0, 0, "global")
    g64 = halo.collective_bytes_model(k, f, 64, 0, 0, "global")
    assert g64 > g8


GNN_HALO_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
jax.config.update("jax_platform_name", "cpu")
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.batching.stream import BatchStream
from repro.core.reorder import prepare
from repro.dist import gnn as dist_gnn
from repro.dist.sharding import shard_map
from repro.graphs import synthetic

g = prepare(synthetic.load("tiny"), oracle=True)
plan = dist_gnn.community_shard_plan(g, 4)
mesh = dist_gnn.make_gnn_mesh(4)
stream = BatchStream(g, "comm_rand", 32, (5, 5), (512, 1024), seed=3)
rb = stream.root_batches(0)[0]
ids = np.stack([np.asarray(stream.build(rb[r * 8:(r + 1) * 8], 0, 0)
                           .node_ids) for r in range(4)])
feats_local = plan.shard_features(g.features, mesh)
pos = plan.device_pos(mesh)
ids_sh = jax.device_put(jnp.asarray(ids), NamedSharding(mesh, P("shard")))
want = np.where((ids < g.num_nodes)[..., None],
                np.asarray(g.features)[np.minimum(ids, g.num_nodes - 1)],
                0.0)

for hplan in (dist_gnn.HaloPlan("halo", 2, ids.shape[1]),
              dist_gnn.HaloPlan("global", 0, 0)):
    def f(fl, p, il):
        rows, dropped = dist_gnn.gather_batch_features(
            fl, p, il[0], plan, hplan)
        return rows[None], dropped[None]
    fn = jax.jit(shard_map(
        f, mesh, (P("shard", None), P(), P("shard")),
        (P("shard"), P("shard"))))
    out, dropped = fn(feats_local, pos, ids_sh)
    assert int(np.asarray(dropped).sum()) == 0, hplan
    np.testing.assert_array_equal(np.asarray(out), want)
print("GNN_HALO_OK")

# starved ring budget: drops are counted, rows never corrupted
hplan = dist_gnn.HaloPlan("halo", 1, 2)
def f2(fl, p, il):
    rows, dropped = dist_gnn.gather_batch_features(
        fl, p, il[0], plan, hplan)
    return rows[None], dropped[None]
out, dropped = jax.jit(shard_map(
    f2, mesh, (P("shard", None), P(), P("shard")),
    (P("shard"), P("shard"))))(feats_local, pos, ids_sh)
assert int(np.asarray(dropped).sum()) > 0
out = np.asarray(out)
for r in range(4):
    for j in range(ids.shape[1]):
        assert np.array_equal(out[r, j], want[r, j]) or not out[r, j].any()
print("GNN_HALO_DROP_OK")
"""


def test_gnn_halo_gather_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", GNN_HALO_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "GNN_HALO_OK" in out.stdout and "GNN_HALO_DROP_OK" in out.stdout, \
        (out.stdout, out.stderr[-3000:])
