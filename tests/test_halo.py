"""Distributed halo-gather correctness (subprocess, 8 fake devices):
halo/global gathers must equal a naive full gather for in-budget ids."""
import os
import subprocess
import sys

HALO_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import halo
from repro.dist.sharding import shard_map

D, Ns, F, K = 8, 32, 16, 24
mesh = Mesh(np.array(jax.devices()[:D]), ("shard",))
feats = jnp.arange(D * Ns * F, dtype=jnp.float32).reshape(D * Ns, F)
feats_sh = jax.device_put(feats, NamedSharding(mesh, P("shard", None)))

rng = np.random.default_rng(0)
# per-device requests: mostly own-shard + neighbors within +-2
ids = np.zeros((D, K), np.int32)
for d in range(D):
    own = rng.integers(d * Ns, (d + 1) * Ns, K - 6)
    nb = [(rng.integers(((d + s) % D) * Ns, ((d + s) % D + 1) * Ns))
          for s in (1, 1, 2, -1, -2, -2)]
    ids[d] = np.concatenate([own, np.array(nb)])
ids_sh = jax.device_put(jnp.asarray(ids),
                        NamedSharding(mesh, P("shard", None)))

for mode, r_cap, h in (("halo", 8, 2), ("global", 0, 0)):
    fn = jax.jit(shard_map(
        lambda f, i: tuple(x[None] for x in halo.gather_for_policy(
            f, i[0], n_per_shard=Ns, r_cap=r_cap, halo=h, mode=mode)),
        mesh=mesh, in_specs=(P("shard", None), P("shard", None)),
        out_specs=(P("shard", None, None), P("shard"))))
    out, dropped = fn(feats_sh, ids_sh)
    ref = np.asarray(feats)[ids]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)
    assert int(np.asarray(dropped).sum()) == 0, mode
print("HALO_OK")

# out-of-budget ids are dropped and counted, not wrong
ids2 = ids.copy(); ids2[:, 0] = (ids[:, 0] + 4 * Ns) % (D * Ns)
ids2_sh = jax.device_put(jnp.asarray(ids2), NamedSharding(mesh, P("shard", None)))
fn = jax.jit(shard_map(
    lambda f, i: tuple(x[None] for x in halo.gather_for_policy(
        f, i[0], n_per_shard=Ns, r_cap=8, halo=2, mode="halo")),
    mesh=mesh, in_specs=(P("shard", None), P("shard", None)),
    out_specs=(P("shard", None, None), P("shard"))))
out, dropped = fn(feats_sh, ids2_sh)
assert int(np.asarray(dropped).sum()) > 0
print("HALO_DROP_OK")
"""


def test_halo_gather_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", HALO_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "HALO_OK" in out.stdout and "HALO_DROP_OK" in out.stdout, \
        out.stderr[-3000:]
