"""Data-parallel GNN training throughput (`repro.dist.gnn`) on a forced
4-device CPU mesh, written to `BENCH_dist.json`.

The driver trains the smoke config on the community-sharded mesh and
reports, per replica and in aggregate:

  batches_per_s           global sharded-step dispatch rate (an SPMD
                          step is ONE dispatch for all replicas)
  roots_per_s_per_replica each replica consumes B/D roots of every
                          global batch: batches_per_s * (B/D)
  straggler_fraction      host dispatch-time outliers
                          (`train.monitor.StragglerMonitor`, the same
                          series the single-device trainer exports)
  halo_plan / halo_bytes  the epoch's planned exchange mode + modeled
                          collective bytes per gather and per epoch
                          (`core.halo.collective_bytes_model`)
  replica_rollups         per-replica loss share / halo drops / cache
                          counters, reconstructed from the sharded
                          step's aux outputs via `ReplicaTraceEmitter`
                          (one Perfetto pid per replica)

plus a `bit_identity` verdict: a 1-replica mesh losses-`==` the
single-device trainer over the probe steps — the determinism headline
of the sharded path, asserted by CI on every run.

    PYTHONPATH=src python benchmarks/dist_bench.py [--smoke]

CPU-simulated mesh numbers are layout/contract validation, not kernel
perf (see the `_meta` note in the artifact).
"""
from __future__ import annotations

import os

# the forced multi-device CPU topology must exist BEFORE jax initializes
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from benchmarks.common import _REPO_ROOT, dataset, write_bench_json  # noqa: E402
from repro.configs.base import GNNConfig, TrainConfig                # noqa: E402
from repro.dist import gnn as dist_gnn                               # noqa: E402
from repro.obs import report as obs_report                           # noqa: E402
from repro.obs import trace as obs_trace                             # noqa: E402
from repro.train.gnn_loop import GNNTrainer                          # noqa: E402

BENCH_DIST_JSON = os.path.join(_REPO_ROOT, "BENCH_dist.json")


def _cfg(g, smoke: bool):
    return GNNConfig(f"sage-{g.name}", "sage", 2, 16 if smoke else 64,
                     g.feat_dim, g.num_classes, fanout=(5, 5))


def _trainer(g, cfg, tcfg, mesh):
    return GNNTrainer(g, cfg, tcfg, "comm_rand", caps=(512, 1024),
                      eval_caps=(512, 1024), seed=3, mesh=mesh)


def bit_identity_probe(g, cfg, tcfg, steps: int = 8) -> bool:
    """1-replica mesh vs plain single-device: exact `==` on the loss
    trajectory (the tests pin the params digest too; the bench keeps a
    fast standing verdict in the artifact)."""
    a = _trainer(g, cfg, tcfg, None)
    b = _trainer(g, cfg, tcfg, dist_gnn.make_gnn_mesh(1))
    return a.train_steps(steps) == b.train_steps(steps)


def run(smoke: bool) -> dict:
    d = jax.device_count()
    g = dataset("tiny" if smoke else "small")
    cfg = _cfg(g, smoke)
    tcfg = TrainConfig(batch_size=32 if smoke else 256, max_epochs=2)
    mesh = dist_gnn.make_gnn_mesh(d)
    tr = _trainer(g, cfg, tcfg, mesh)
    tr.warmup()

    trace_path = os.path.join(_REPO_ROOT, "benchmarks", "artifacts",
                              "dist_trace.jsonl")
    os.makedirs(os.path.dirname(trace_path), exist_ok=True)
    if os.path.exists(trace_path):
        os.remove(trace_path)
    with obs_trace.enabled(trace_path, run="dist_bench") as tracer:
        t0 = time.perf_counter()
        em = tr.run_epoch(tcfg.learning_rate)
        wall = time.perf_counter() - t0
        tracer.flush()
    n_batches = tr.stream.num_batches()
    hplan = tr._hplan
    bytes_per_gather = hplan.bytes_per_gather(tr.caps[-1], g.feat_dim, d)

    evs = obs_report.load_trace(trace_path)
    rollups = [ev["args"] for ev in evs if ev["name"] == "replica_rollup"]
    per_pid = obs_report.analyze(evs)["mid_epoch_sync_by_pid"]

    return {
        "dataset": g.name,
        "n_replicas": d,
        "batch_size": tcfg.batch_size,
        "n_batches": n_batches,
        "epoch_loss": em["loss"],
        "batches_per_s": n_batches / max(em["time"], 1e-9),
        "roots_per_s_per_replica":
            n_batches / max(em["time"], 1e-9) * (tcfg.batch_size / d),
        "straggler_fraction": em["straggler"],
        "wall_s": wall,
        "halo_plan": {"mode": hplan.mode, "halo": hplan.halo,
                      "r_cap": hplan.r_cap},
        "halo_bytes_per_gather": bytes_per_gather,
        "halo_bytes_per_epoch": bytes_per_gather * n_batches,
        "replica_rollups": rollups,
        "mid_epoch_sync_by_pid": per_pid,
        "mid_epoch_syncs_total": sum(per_pid.values()),
        "bit_identity": bit_identity_probe(g, cfg, tcfg),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, 2 epochs — the CI configuration")
    args = ap.parse_args()
    rep = run(smoke=args.smoke)
    assert rep["n_replicas"] == 4, (
        "dist bench expects the forced 4-device CPU mesh; got "
        f"{rep['n_replicas']} (is XLA_FLAGS overridden?)")
    write_bench_json({"dist/gnn": rep}, path=BENCH_DIST_JSON)
    print(json.dumps(rep, indent=1, sort_keys=True))


if __name__ == "__main__":
    main()
