"""Sampler sweep: per-sampler batch-build timing + mean unique-node
footprint on reddit-like, merged into the machine-readable bench artifact
(`BENCH_kernels.json`) alongside the kernel entries.

Also times the vectorized `graphs.csr.reorder` in the real preprocessing
path (community permutation of the full edge array) — the old per-node
Python loop was the preprocessing bottleneck on big graphs.

The sweep doubles as the §6.3 acceptance evidence: the device-side LABOR
sampler's mean footprint must land strictly below uniform/rand's at equal
fanout, with zero community information.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, timer_us, write_bench_json
from repro import sampling
from repro.core import minibatch as mb
from repro.pipeline.builder import stage_times
from repro.core.reorder import community_permutation
from repro.graphs import synthetic
from repro.graphs.csr import DeviceGraph, reorder

GRAPH = "reddit-like"
BATCH = 512
FANOUTS = (10, 10)
SWEEP = (("biased", {"p": 0.5}), ("biased", {"p": 1.0}), ("uniform", {}),
         ("labor", {}), ("full", {}))


def _bench_reorder(entries):
    g_raw = synthetic.load(GRAPH)           # unprepared: random node order
    perm = community_permutation(g_raw.communities, g_raw.degrees())
    t0 = time.perf_counter()
    reorder(g_raw, perm)
    us = (time.perf_counter() - t0) * 1e6
    emit(f"preprocess/reorder/{GRAPH}", us, f"edges={g_raw.num_edges}")
    entries[f"preprocess/reorder/{GRAPH}"] = {
        "us": us, "edges": int(g_raw.num_edges),
        "impl": "vectorized argsort/gather"}


def main(full: bool = False):
    g = dataset(GRAPH)
    gd = DeviceGraph.from_graph(g)
    labels = jnp.asarray(g.labels)
    caps = (8192, g.num_nodes + 128)        # generous: no dedup truncation
    rng = np.random.default_rng(0)
    n_batches = 6 if full else 3
    batches = [np.sort(rng.choice(g.train_ids, BATCH, replace=False))
               for _ in range(n_batches)]
    epoch_key = jax.random.key(0)

    entries = {}
    foot = {}
    for name, kw in SWEEP:
        s = sampling.make_sampler(name, **kw)
        fanouts = FANOUTS      # "full" at the same fanout: first-k truncation

        def build(j):
            return mb.build_batch(
                jax.random.fold_in(epoch_key, j), gd,
                jnp.asarray(batches[j], jnp.int32), labels, fanouts, caps,
                s, epoch_key=epoch_key)

        us = timer_us(build, 0, warmup=1, iters=3)
        uniq = float(np.mean([int(build(j).num_unique)
                              for j in range(n_batches)]))
        # per-stage split (roots prep / neighbor sample / dedup+remap) of
        # the same build — where each sampler actually spends its time
        bd = stage_times(gd, jnp.asarray(batches[0], jnp.int32), labels,
                         fanouts, caps, s,
                         key=jax.random.fold_in(epoch_key, 0),
                         epoch_key=epoch_key, iters=6 if full else 3)
        foot[s.describe()] = uniq
        emit(f"sampler_sweep/{GRAPH}/{s.describe()}", us,
             f"mean_unique_nodes={uniq:.0f}")
        entries[f"sampler_sweep/{s.describe()}"] = {
            "build_us": us, "mean_unique_nodes": uniq, "graph": GRAPH,
            "batch": BATCH, "fanouts": list(fanouts),
            "build_breakdown_us": {k: round(v, 1) for k, v in bd.items()}}

    # §6.3 acceptance: shared-randomness LABOR beats independent sampling
    # on footprint at equal fanout, without community info
    assert foot["labor(shared-hash-topk)"] < foot["uniform"], foot
    assert foot["labor(shared-hash-topk)"] < foot["biased-two-phase(p=0.5)"]

    _bench_reorder(entries)
    write_bench_json(entries)


if __name__ == "__main__":
    main()
