"""Paper Table 3: fixed wall-clock training budget — COMM-RAND completes
more epochs and reaches better accuracy."""
from __future__ import annotations

import time

from benchmarks.common import POLICIES, calibrator, dataset, emit, gnn_cfg
from repro.configs.base import TrainConfig
from repro.train.gnn_loop import GNNTrainer


def main(full: bool = False, budget_s: float = None):
    g = dataset("reddit-like" if full else "tiny")
    cfg = gnn_cfg(g)
    budget_s = budget_s or (60.0 if full else 8.0)
    for name in ("RAND-ROOTS/p0.5", "COMM-RAND-MIX-12.5%/p1.0"):
        pol = POLICIES[name]
        tcfg = TrainConfig(batch_size=512, max_epochs=10_000)
        tr = GNNTrainer(g, cfg, tcfg, pol, seed=0,
                        calibrator=calibrator()).warmup()
        t0 = time.perf_counter()
        epochs = 0
        lr = tcfg.learning_rate
        while time.perf_counter() - t0 < budget_s:
            tr.run_epoch(lr)
            epochs += 1
        ev = tr.evaluate(g.val_ids)
        te = tr.evaluate(g.test_ids)
        emit(f"table3/{g.name}/{name}", budget_s * 1e6,
             f"epochs={epochs};val_acc={ev['acc']:.4f};"
             f"test_acc={te['acc']:.4f}")


if __name__ == "__main__":
    main()
