"""Kernel microbenchmarks. On this CPU container Pallas executes in
interpret mode, so the us_per_call column is SHAPE-VALIDATION only; the
`derived` column carries the analytic FLOPs/bytes used by the roofline."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timer_us
from repro.kernels.gather_mean.ref import gather_mean_ref
from repro.models.lm.attention import flash_attention
from repro.models.lm.rwkv6 import wkv6_chunked


def main(full: bool = False):
    key = jax.random.key(0)

    # gather_mean (jnp ref path — the Pallas twin is interpret-only here)
    x = jax.random.normal(key, (4096, 128))
    idx = jax.random.randint(jax.random.key(1), (1024, 10), 0, 4096)
    mask = jnp.ones((1024, 10), bool)
    f = jax.jit(gather_mean_ref)
    us = timer_us(f, x, idx, mask)
    emit("kernel/gather_mean/1024x10x128", us,
         f"bytes={1024 * 10 * 128 * 4}")

    # flash attention fwd+bwd
    q = jax.random.normal(jax.random.key(2), (1, 1024, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(3), (1, 1024, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(4), (1, 1024, 2, 64), jnp.bfloat16)
    g = jax.jit(jax.grad(lambda q, k, v: flash_attention(
        q, k, v).astype(jnp.float32).sum(), argnums=(0,)))
    us = timer_us(g, q, k, v)
    flops = 4 * 1024 * 1024 * 4 * 64 * 2   # fwd+bwd qk+pv per head
    emit("kernel/flash_attention/1k_seq", us, f"flops={flops}")

    # rwkv6 chunked
    B, T, H, N = 1, 1024, 8, 64
    r = jax.random.normal(jax.random.key(5), (B, T, H, N))
    kk = jax.random.normal(jax.random.key(6), (B, T, H, N))
    vv = jax.random.normal(jax.random.key(7), (B, T, H, N))
    lw = jnp.clip(-jnp.exp(jax.random.normal(jax.random.key(8),
                                             (B, T, H, N))), -5, -1e-4)
    u = jax.random.normal(jax.random.key(9), (H, N)) * 0.1
    s0 = jnp.zeros((B, H, N, N))
    f = jax.jit(lambda *a: wkv6_chunked(*a)[0])
    us = timer_us(f, r, kk, vv, lw, u, s0)
    emit("kernel/rwkv6_chunked/1k_seq", us,
         f"flops~={T * H * (16 * 16 * N * 2 + 2 * N * N * 2)}")

    # moe grouped matmul (ref einsum)
    from repro.kernels.moe_gmm.ref import moe_gmm_ref
    xg = jax.random.normal(jax.random.key(10), (8, 256, 256), jnp.bfloat16)
    w = jax.random.normal(jax.random.key(11), (8, 256, 512), jnp.bfloat16)
    f = jax.jit(moe_gmm_ref)
    us = timer_us(f, xg, w)
    emit("kernel/moe_gmm/8x256x256x512", us,
         f"flops={2 * 8 * 256 * 256 * 512}")


if __name__ == "__main__":
    main()
