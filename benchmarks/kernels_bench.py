"""Kernel microbenchmarks. On this CPU container Pallas executes in
interpret mode, so the us_per_call column is SHAPE-VALIDATION only; the
`derived` column carries the analytic FLOPs/bytes used by the roofline.
Results also land in BENCH_kernels.json at the repo root (see
`common.write_bench_json`) so the perf trajectory is machine-readable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timer_us, write_bench_json
from repro.kernels.gather_agg.ops import gather_agg
from repro.kernels.gather_agg.ref import gather_agg_ref
from repro.models.lm.attention import flash_attention
from repro.models.lm.rwkv6 import wkv6_chunked


def gather_agg_hbm_bytes(D: int, r: int, F: int, fused: bool) -> int:
    """Analytic HBM traffic of one aggregation (f32). The jnp/XLA path
    materializes the (D, r, F) gathered intermediate (write + re-read for
    the reduce); the fused kernel streams gathered rows straight into the
    revisited (bd, F) output tile."""
    gather_read = D * r * F * 4
    out_write = D * F * 4
    idx_w = D * r * (4 + 4)
    if fused:
        return gather_read + out_write + idx_w
    intermediate = 2 * D * r * F * 4            # write + re-read
    return gather_read + intermediate + out_write + idx_w


def main(full: bool = False):
    key = jax.random.key(0)
    entries = {}

    # fused gather-aggregate vs jnp reference (the GNN aggregation hot loop)
    D, r, F, N = 1024, 10, 128, 4096
    x = jax.random.normal(key, (N, F))
    idx = jax.random.randint(jax.random.key(1), (D, r), 0, N)
    w = jax.random.normal(jax.random.key(12), (D, r))
    f_ref = jax.jit(gather_agg_ref)
    us_ref = timer_us(f_ref, x, idx, w)
    f_pal = jax.jit(lambda x, idx, w: gather_agg(x, idx, w, impl="pallas"))
    us_pal = timer_us(f_pal, x, idx, w)
    for name, us, fused in [("jnp", us_ref, False), ("pallas", us_pal, True)]:
        b = gather_agg_hbm_bytes(D, r, F, fused)
        emit(f"kernel/gather_agg/{name}/1024x10x128", us, f"hbm_bytes={b}")
        entries[f"gather_agg/{name}/1024x10x128"] = {
            "us_per_call": round(us, 1), "hbm_bytes": b,
            "shape": {"n_dst": D, "fanout": r, "feat": F, "n_src": N}}
    # structural regression guard (what the analytic model claims, checked
    # against the actual lowering): the jnp path materializes the
    # (D, r, F) gathered edge tensor, the fused path must never
    edge_tensor = f"f32[{D},{r},{F}]"
    jx_ref = str(jax.make_jaxpr(gather_agg_ref)(x, idx, w))
    jx_pal = str(jax.make_jaxpr(
        lambda x, idx, w: gather_agg(x, idx, w, impl="pallas"))(x, idx, w))
    entries["gather_agg/ref_materializes_edge_tensor"] = edge_tensor in jx_ref
    entries["gather_agg/fused_avoids_edge_tensor"] = \
        edge_tensor not in jx_pal
    assert entries["gather_agg/fused_avoids_edge_tensor"]

    # flash attention fwd+bwd
    q = jax.random.normal(jax.random.key(2), (1, 1024, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(3), (1, 1024, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(4), (1, 1024, 2, 64), jnp.bfloat16)
    g = jax.jit(jax.grad(lambda q, k, v: flash_attention(
        q, k, v).astype(jnp.float32).sum(), argnums=(0,)))
    us = timer_us(g, q, k, v)
    flops = 4 * 1024 * 1024 * 4 * 64 * 2   # fwd+bwd qk+pv per head
    emit("kernel/flash_attention/1k_seq", us, f"flops={flops}")
    entries["flash_attention/1k_seq"] = {"us_per_call": round(us, 1),
                                         "flops": flops}

    # rwkv6 chunked
    B, T, H, Nn = 1, 1024, 8, 64
    r_ = jax.random.normal(jax.random.key(5), (B, T, H, Nn))
    kk = jax.random.normal(jax.random.key(6), (B, T, H, Nn))
    vv = jax.random.normal(jax.random.key(7), (B, T, H, Nn))
    lw = jnp.clip(-jnp.exp(jax.random.normal(jax.random.key(8),
                                             (B, T, H, Nn))), -5, -1e-4)
    u = jax.random.normal(jax.random.key(9), (H, Nn)) * 0.1
    s0 = jnp.zeros((B, H, Nn, Nn))
    f = jax.jit(lambda *a: wkv6_chunked(*a)[0])
    us = timer_us(f, r_, kk, vv, lw, u, s0)
    rk_flops = T * H * (16 * 16 * Nn * 2 + 2 * Nn * Nn * 2)
    emit("kernel/rwkv6_chunked/1k_seq", us, f"flops~={rk_flops}")
    entries["rwkv6_chunked/1k_seq"] = {"us_per_call": round(us, 1),
                                       "flops": rk_flops}

    # moe grouped matmul (ref einsum)
    from repro.kernels.moe_gmm.ref import moe_gmm_ref
    xg = jax.random.normal(jax.random.key(10), (8, 256, 256), jnp.bfloat16)
    wg = jax.random.normal(jax.random.key(11), (8, 256, 512), jnp.bfloat16)
    f = jax.jit(moe_gmm_ref)
    us = timer_us(f, xg, wg)
    gmm_flops = 2 * 8 * 256 * 256 * 512
    emit("kernel/moe_gmm/8x256x256x512", us, f"flops={gmm_flops}")
    entries["moe_gmm/8x256x256x512"] = {"us_per_call": round(us, 1),
                                        "flops": gmm_flops}

    write_bench_json(entries)


if __name__ == "__main__":
    main()
