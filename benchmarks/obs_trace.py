"""Traced async smoke run: the CI artifact behind `repro.obs`'s claims.

Drives the async comm_rand x LABOR + dynamic-cache trainer (the soak
configuration, guard and checkpointing off — both would add sanctioned
per-step syncs that belong to OTHER benches) for a few epochs with the
span tracer installed, then:

  1. runs the trace analyzer (`repro.obs.report`) and ASSERTS the two
     runtime claims the static lint cannot prove:
       - producer/consumer overlap fraction > 0 (the async prefetcher
         really hides batch construction behind train steps)
       - zero mid-epoch host-sync spans (every cat="sync" span sits at
         an epoch boundary)
  2. re-runs the SAME training untraced and asserts the per-epoch loss
     trajectory is BIT-IDENTICAL — tracing is observation, not
     perturbation
  3. merges the numbers + the MetricsHub export into `BENCH_obs.json`
     and writes the trace (JSONL + Perfetto traceEvents) under
     benchmarks/artifacts/ for `python -m repro.obs` / ui.perfetto.dev.

    PYTHONPATH=src python benchmarks/obs_trace.py [--smoke]
"""
from __future__ import annotations

import argparse
import os

from benchmarks.common import _REPO_ROOT, dataset, emit, write_bench_json
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.resilience import soak

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")
TRACE_JSONL = os.path.join(ARTIFACTS, "obs_trace.jsonl")
TRACE_CHROME = os.path.join(ARTIFACTS, "obs_trace_chrome.json")
BENCH_OBS = os.path.join(_REPO_ROOT, "BENCH_obs.json")


def _run_epochs(g, epochs: int, traced: bool):
    """The soak trainer config (async pipeline, dynamic cache), guard and
    ckpt off; returns (per-epoch dicts, trainer)."""
    tr = soak.make_trainer(g, pipeline="async", guard=None, ckpt_dir=None)
    tr.warmup()
    out = [tr.run_epoch(1e-3) for _ in range(epochs)]
    tr.stream.close()
    return out, tr


def main(smoke: bool = False):
    epochs = 2 if smoke else 4
    g = dataset("tiny")
    os.makedirs(ARTIFACTS, exist_ok=True)

    with obs_trace.enabled(TRACE_JSONL, run="obs_smoke",
                           pipeline="async") as tracer:
        traced, trainer = _run_epochs(g, epochs, traced=True)
        tracer.flush()

    events = obs_report.load_trace(TRACE_JSONL)
    rep = obs_report.analyze(events)
    obs_report.to_chrome(events, TRACE_CHROME)

    ov = rep["overlap"]
    emit("obs/overlap", ov["overlap_s"] * 1e6,
         f"frac={ov['overlap_frac']:.3f} "
         f"producer_busy={ov['producer_busy_s']:.3f}s")
    emit("obs/mid_epoch_syncs", 0.0, f"count={rep['mid_epoch_sync_count']}")
    for name, e in sorted(rep["stalls"].items()):
        emit(f"obs/stall/{name}", e["total_s"] * 1e6,
             f"count={e['count']} frac={e['frac_of_wall']:.3f}")

    # claim 1: the async producer genuinely overlaps consumer steps
    assert ov["overlap_frac"] > 0, \
        f"no producer/consumer overlap measured: {ov}"
    # claim 2: every host sync sits at an epoch boundary
    assert rep["mid_epoch_sync_count"] == 0, \
        f"mid-epoch syncs: {[e['mid_epoch_sync_names'] for e in rep['epochs']]}"
    assert not rep["conformance_problems"], rep["conformance_problems"][:5]

    # claim 3: tracing is bit-exact — untraced run, same trajectory
    untraced, _ = _run_epochs(g, epochs, traced=False)
    t_loss = [e["loss"] for e in traced]
    u_loss = [e["loss"] for e in untraced]
    emit("obs/bit_exact", 0.0, f"traced==untraced: {t_loss == u_loss}")
    assert t_loss == u_loss, \
        f"tracing perturbed the loss trajectory: {t_loss} != {u_loss}"

    entries = {
        "obs/overlap": {k: round(v, 6) for k, v in ov.items()},
        "obs/stalls": {k: {"count": e["count"],
                           "total_s": round(e["total_s"], 6)}
                       for k, e in rep["stalls"].items()},
        "obs/sync_sites": {k: e["count"]
                           for k, e in rep["sync_sites"].items()},
        "obs/mid_epoch_sync_count": rep["mid_epoch_sync_count"],
        "obs/epochs": [{"epoch": e["epoch"], "n_steps": e["n_steps"],
                        "dur_s": round(e["dur_s"], 4),
                        "mid_epoch_syncs": e["mid_epoch_syncs"]}
                       for e in rep["epochs"]],
        "obs/bit_exact_loss_trajectory": t_loss == u_loss,
        "obs/n_events": rep["n_events"],
        "obs/hub": trainer.hub.export(),
        "obs/straggler_fraction":
            round(trainer.straggler.straggler_fraction, 4),
        "obs/config": {"graph": "tiny", "epochs": epochs,
                       "pipeline": "async", "guard": None, "ckpt": None,
                       "trace": os.path.relpath(TRACE_JSONL, _REPO_ROOT)},
    }
    write_bench_json(entries, path=BENCH_OBS)
    print(f"trace -> {TRACE_JSONL}")
    print(f"perfetto -> {TRACE_CHROME}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2 epochs (CI); full runs 4")
    main(**vars(ap.parse_args()))
