"""Paper Figure 7: label diversity per batch vs convergence speed."""
from __future__ import annotations

from benchmarks.common import (POLICIES, calibrator, dataset, emit,
                               epoch_batches, gnn_cfg, quick_tcfg)
from repro.core import partition
from repro.train.gnn_loop import train_once


def main(full: bool = False):
    g = dataset("reddit-like" if full else "tiny")
    cfg = gnn_cfg(g)
    tcfg = quick_tcfg(12, batch=128)
    for name, pol in POLICIES.items():
        batches = epoch_batches(g, pol, tcfg.batch_size, seed=0)
        lab = partition.labels_per_batch(batches, g.labels)
        r = train_once(g, cfg, pol, tcfg, seed=0, calibrator=calibrator())
        emit(f"fig7/{g.name}/{name}", r.per_epoch_time_s * 1e6,
             f"labels_per_batch={lab:.2f};epochs={r.epochs_to_converge}")


if __name__ == "__main__":
    main()
