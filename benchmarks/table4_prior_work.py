"""Paper Table 4: baseline vs COMM-RAND vs ClusterGCN vs LABOR after a
fixed number of epochs.

Every mini-batch row — including LABOR — runs through the SAME trained,
jit-compiled `GNNTrainer` pipeline; LABOR's row comes from the device-side
shared-randomness sampler (`repro.sampling.LaborSampler`) that
`make_policy("labor")` binds, with the old numpy footprint estimator
(`labor_lite_epoch_footprint`) kept only as a cross-check column.

`--smoke` is the CI entry point: tiny graph, 2 epochs, asserts the LABOR
footprint lands strictly below rand's.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (POLICIES, calibrator, dataset, emit,
                               epoch_batches, gnn_cfg)
from repro.batching import make_policy
from repro.configs.base import TrainConfig
from repro.train.baselines import (labor_lite_epoch_footprint,
                                   train_clustergcn)
from repro.train.gnn_loop import GNNTrainer


def _train_row(g, cfg, tcfg, policy, epochs):
    tr = GNNTrainer(g, cfg, tcfg, policy, seed=0,
                    calibrator=calibrator()).warmup()
    ems = [tr.run_epoch(tcfg.learning_rate) for _ in range(epochs)]
    return {"time": float(np.mean([e["time"] for e in ems])),
            "uniq": float(np.mean([e["uniq"] for e in ems])),
            "acc": tr.evaluate(g.val_ids)["acc"]}


def main(full: bool = False, smoke: bool = False):
    datasets = ("reddit-like", "products-like") if full else ("tiny",)
    epochs = 25 if full else (2 if smoke else 8)
    for ds in datasets:
        g = dataset(ds)
        # tiny's avg degree (~12) makes fanout 10 ≈ full neighborhood,
        # where LABOR's without-replacement draw degenerates — keep
        # fanout below typical degree so the sampling comparison is real
        cfg = gnn_cfg(g) if full else gnn_cfg(g, fanout=(5, 5))
        batch = 512 if full else 256
        tcfg = TrainConfig(batch_size=batch, max_epochs=epochs)
        rows = {"RAND-ROOTS/p0.5": POLICIES["RAND-ROOTS/p0.5"],
                "COMM-RAND-MIX-12.5%/p1.0":
                    POLICIES["COMM-RAND-MIX-12.5%/p1.0"],
                "LABOR": make_policy("labor")}
        results = {}
        for name, pol in rows.items():
            r = _train_row(g, cfg, tcfg, pol, epochs)
            results[name] = r
            base = results["RAND-ROOTS/p0.5"]
            emit(f"table4/{ds}/{name}", r["time"] * 1e6,
                 f"val_acc={r['acc']:.4f};per_epoch_speedup="
                 f"{base['time'] / r['time']:.2f};"
                 f"unique_nodes={r['uniq']:.0f}")
        cg = train_clustergcn(g, cfg, tcfg, parts_per_batch=2, epochs=epochs)
        emit(f"table4/{ds}/ClusterGCN", cg["per_epoch_time_s"] * 1e6,
             f"val_acc={cg['val_acc']:.4f};per_epoch_speedup="
             f"{results['RAND-ROOTS/p0.5']['time'] / cg['per_epoch_time_s']:.2f}")
        # numpy LABOR-lite estimator: cross-check only (the trained row
        # above is the real device path)
        batches = epoch_batches(g, "labor", batch, seed=0)[:4]
        lf = labor_lite_epoch_footprint(g, batches, cfg.fanout[:2])
        emit(f"table4/{ds}/LABOR-lite-numpy-est", 0.0,
             f"unique_nodes={lf:.0f};device_over_est="
             f"{results['LABOR']['uniq'] / max(lf, 1):.3f}")
        assert results["LABOR"]["uniq"] < results["RAND-ROOTS/p0.5"]["uniq"], \
            "LABOR shared-randomness sampling must shrink the footprint"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: tiny graph, 2 epochs, footprint assertion")
    a = ap.parse_args()
    main(full=a.full, smoke=a.smoke)
