"""Paper Table 4: baseline vs COMM-RAND vs ClusterGCN (+ LABOR-lite
footprint) after a fixed number of epochs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (POLICIES, calibrator, dataset, emit,
                               epoch_batches, gnn_cfg)
from repro.configs.base import TrainConfig
from repro.train.baselines import (labor_lite_epoch_footprint,
                                   train_clustergcn)
from repro.train.gnn_loop import GNNTrainer


def main(full: bool = False):
    datasets = ("reddit-like", "products-like") if full else ("tiny",)
    epochs = 25 if full else 8
    for ds in datasets:
        g = dataset(ds)
        cfg = gnn_cfg(g)
        tcfg = TrainConfig(batch_size=512, max_epochs=epochs)
        results = {}
        for name in ("RAND-ROOTS/p0.5", "COMM-RAND-MIX-12.5%/p1.0"):
            tr = GNNTrainer(g, cfg, tcfg, POLICIES[name], seed=0,
                            calibrator=calibrator()).warmup()
            times = [tr.run_epoch(tcfg.learning_rate)["time"]
                     for _ in range(epochs)]
            acc = tr.evaluate(g.val_ids)["acc"]
            results[name] = (float(np.mean(times)), acc)
            base_t = results["RAND-ROOTS/p0.5"][0]
            emit(f"table4/{ds}/{name}", np.mean(times) * 1e6,
                 f"val_acc={acc:.4f};per_epoch_speedup="
                 f"{base_t / np.mean(times):.2f}")
        cg = train_clustergcn(g, cfg, tcfg, parts_per_batch=2, epochs=epochs)
        emit(f"table4/{ds}/ClusterGCN", cg["per_epoch_time_s"] * 1e6,
             f"val_acc={cg['val_acc']:.4f};per_epoch_speedup="
             f"{results['RAND-ROOTS/p0.5'][0] / cg['per_epoch_time_s']:.2f}")
        # LABOR-lite: structure-agnostic variance reduction (footprint only)
        batches = epoch_batches(g, "labor", 512, seed=0)[:4]
        lf = labor_lite_epoch_footprint(g, batches, cfg.fanout[:2])
        emit(f"table4/{ds}/LABOR-lite", 0.0,
             f"unique_nodes={lf:.0f}")


if __name__ == "__main__":
    main()
