"""Chaos soak driver: every fault class injected into a guarded
comm_rand x LABOR + dynamic-cache run, recovery scored bit-for-bit
against a fault-free reference (`repro.resilience.soak`). Results merge
into `BENCH_resilience.json` under `chaos/<scenario>`:

  ok            fault fired AND expected recovery ran AND the final loss
                trajectory + params digest are BIT-IDENTICAL to the
                fault-free run (the artifact CI asserts on)
  fired         armed fires of the scenario's site (0 proves nothing)
  bitmatch      exact == over {step: loss}, so a NaN any recovery failed
                to replay can never pass
  recovered     the scenario's expected ResilienceMeter counter engaged
  meter         all recovery counters (rollbacks, restarts, fallbacks,
                degradations, skipped steps)
  wall_s        scenario wall time (recovery overhead, not throughput)

    PYTHONPATH=src python benchmarks/chaos_soak.py [--smoke]

--smoke runs one seed at the soak's default 20 steps (CI); the full run
adds a second seed so the trigger points move.
"""
from __future__ import annotations

import argparse
import os
import time

from benchmarks.common import _REPO_ROOT, dataset, emit, write_bench_json
from repro.resilience import faults, soak


def main(smoke: bool = False):
    g = dataset("tiny")
    n = soak.N_STEPS
    seeds = (11,) if smoke else (11, 23)

    entries = {}
    all_ok = True
    ref = soak.run_reference(g, n)
    for seed in seeds:
        for site in faults.FAULT_SITES:
            t0 = time.perf_counter()
            res = soak.run_scenario(g, site, n=n, seed=seed, ref=ref)
            wall = time.perf_counter() - t0
            key = f"chaos/{site}" if len(seeds) == 1 \
                else f"chaos/{site}/seed{seed}"
            entries[key] = dict(res.summary(), seed=seed,
                                wall_s=round(wall, 2))
            emit(key, wall * 1e6,
                 f"ok={res.ok} fired={res.fired} "
                 f"bitmatch={res.bitmatch} "
                 f"meter={ {k: v for k, v in res.meter.items() if v} }")
            all_ok = all_ok and res.ok

    entries["chaos/_summary"] = {
        "ok": all_ok, "scenarios": len(seeds) * len(faults.FAULT_SITES),
        "n_steps": n, "graph": "tiny",
        "guard": {"max_consecutive_skips": soak.GUARD.max_consecutive_skips,
                  "check_every": soak.GUARD.check_every,
                  "max_rollbacks": soak.GUARD.max_rollbacks}}
    write_bench_json(entries, path=os.path.join(_REPO_ROOT,
                                                "BENCH_resilience.json"))
    assert all_ok, "chaos soak: a scenario failed bit-exact recovery"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one seed (CI); full adds a second seed")
    main(**vars(ap.parse_args()))
