"""Batch-pipeline throughput: sync `BatchStream` vs `repro.pipeline`'s
async prefetcher, merged into `BENCH_kernels.json` under `pipeline/*`.

Per variant the bench drives a consumer loop that mimics a train step (a
jitted stack of matmuls over the batch's gathered feature rows) and
measures:

  batches_per_s       delivered batch rate (MEDIAN over repeated runs,
                      consumer work included) — plus best_batches_per_s
                      (max over runs) and iqr_batches_per_s (p75 - p25,
                      the run-to-run noise band; a speedup smaller than
                      the IQR is noise, not signal)
  consumer_stall_frac fraction of wall time the consumer spends BLOCKED
                      waiting for the next batch (the device-idle proxy:
                      while the consumer is stalled there is no train
                      step in flight); median run's value
  us_per_batch        1e6 / batches_per_s (median)

plus the per-stage build breakdown (`pipeline/build_breakdown`: roots /
sample / dedup, from `repro.pipeline.stage_times`) and the device-order
bit-match verdict for every registered policy
(`pipeline/order_bitmatch`, the mirror contract CI asserts on).

    PYTHONPATH=src python benchmarks/pipeline_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dataset, emit, write_bench_json
from repro.batching import BatchStream, make_policy
from repro.pipeline import AsyncBatchStream, order_bitmatch
from repro.pipeline.builder import stage_times

POLICY = ("comm_rand", {"mix": 0.125, "p": 1.0})
FANOUTS = (10, 10)
ALL_POLICIES = (("rand", {}), ("norand", {}),
                ("comm_rand", {"mix": 0.125}), ("clustergcn", {}),
                ("labor", {}))


def _consumer(feats, dim: int, depth: int = 4):
    """A stand-in train step: gather the batch's feature rows, push them
    through `depth` jitted matmuls. Heavy enough that an async producer
    has real device time to hide behind."""
    w = jax.random.normal(jax.random.key(42), (dim, dim),
                          jnp.float32) / np.sqrt(dim)

    @jax.jit
    def step(ids, mask):
        x = feats[jnp.minimum(ids, feats.shape[0] - 1)]
        x = x * mask[:, None]
        for _ in range(depth):
            x = jnp.tanh(x @ w)
        return x.sum()

    return step


def _drive(stream, step, n: int, warm: int = 3) -> dict:
    """Pull `n` batches through `stream`, running `step` per batch; split
    wall time into waiting-for-batch vs consumer work."""
    it = iter(stream)
    for _ in range(warm):                       # compile + fill the queue
        b = next(it)
        jax.block_until_ready(step(b.node_ids, b.node_mask))
    wait = 0.0
    t0 = time.perf_counter()
    for _ in range(n):
        ta = time.perf_counter()
        b = next(it)
        jax.block_until_ready(b.node_ids)       # batch ready to consume
        wait += time.perf_counter() - ta
        jax.block_until_ready(step(b.node_ids, b.node_mask))
    total = time.perf_counter() - t0
    return {"batches_per_s": n / total,
            "us_per_batch": total / n * 1e6,
            "consumer_stall_frac": wait / total}


def main(smoke: bool = False):
    graph_name = "tiny" if smoke else "reddit-like"
    batch = 256 if smoke else 512
    n = 12 if smoke else 60
    g = dataset(graph_name)
    caps = (4096, 8192) if smoke else (8192, 16384)
    pol = make_policy(POLICY[0], **POLICY[1])
    kw = dict(batch_size=batch, fanouts=FANOUTS, caps=caps, seed=0)

    entries = {}

    # mirror contract first: device epoch order == numpy, all policies
    bitmatch = {}
    for name, pkw in ALL_POLICIES:
        bitmatch[name] = bool(order_bitmatch(
            g, make_policy(name, **pkw), seed=0, epochs=(0, 1)))
        emit(f"pipeline/order_bitmatch/{name}", 0.0,
             f"bitmatch={bitmatch[name]}")
    entries["pipeline/order_bitmatch"] = dict(bitmatch, graph=graph_name)

    feats = jnp.asarray(g.features, jnp.float32)
    step = _consumer(feats, g.feat_dim)

    runs = 3 if smoke else 5

    def measure(factory):
        """Repeated measurement, fresh stream each run: report the MEDIAN
        run (robust central tendency on shared CI runners) alongside the
        best and the IQR noise band — best-of-2 hid the spread entirely."""
        results = []
        for _ in range(runs):
            stream = factory()
            try:
                results.append(_drive(stream, step, n))
            finally:
                getattr(stream, "close", lambda: None)()
        results.sort(key=lambda r: r["batches_per_s"])
        rates = [r["batches_per_s"] for r in results]
        med = dict(results[len(results) // 2])  # median-rate run's stats
        med["batches_per_s"] = float(np.median(rates))
        med["us_per_batch"] = 1e6 / med["batches_per_s"]
        med["best_batches_per_s"] = max(rates)
        med["iqr_batches_per_s"] = float(np.percentile(rates, 75)
                                         - np.percentile(rates, 25))
        med["runs"] = [round(r, 2) for r in rates]
        return med

    sync = BatchStream(g, pol, **kw)      # kept for breakdown inputs below
    res_sync = measure(lambda: BatchStream(g, pol, **kw))
    emit(f"pipeline/sync/{graph_name}", res_sync["us_per_batch"],
         f"batches_per_s={res_sync['batches_per_s']:.1f} "
         f"iqr={res_sync['iqr_batches_per_s']:.1f} "
         f"stall={res_sync['consumer_stall_frac']:.3f}")
    entries["pipeline/sync"] = dict(res_sync, graph=graph_name,
                                    batch=batch)

    res_async = measure(lambda: AsyncBatchStream(g, pol, **kw))
    emit(f"pipeline/async/{graph_name}", res_async["us_per_batch"],
         f"batches_per_s={res_async['batches_per_s']:.1f} "
         f"iqr={res_async['iqr_batches_per_s']:.1f} "
         f"stall={res_async['consumer_stall_frac']:.3f}")
    entries["pipeline/async"] = dict(res_async, graph=graph_name,
                                     batch=batch, depth=2)

    speedup = res_async["batches_per_s"] / res_sync["batches_per_s"]
    best_speedup = (res_async["best_batches_per_s"]
                    / res_sync["best_batches_per_s"])
    emit(f"pipeline/speedup/{graph_name}", 0.0,
         f"async/sync={speedup:.3f} best={best_speedup:.3f}")
    entries["pipeline/speedup"] = {"async_over_sync": speedup,
                                   "best_async_over_sync": best_speedup,
                                   "runs": runs,
                                   "graph": graph_name}

    # per-stage split of one representative batch build
    roots = sync.root_batches(0)[0]
    bd = stage_times(sync.g, jnp.asarray(roots, jnp.int32), sync.labels,
                     FANOUTS, caps, sync.sampler,
                     key=sync.batch_key(0, 0),
                     epoch_key=sync.epoch_key(0),
                     iters=3 if smoke else 10)
    emit(f"pipeline/build_breakdown/{graph_name}",
         sum(bd.values()),
         " ".join(f"{k}={v:.0f}" for k, v in bd.items()))
    entries["pipeline/build_breakdown"] = dict(
        {k: round(v, 1) for k, v in bd.items()},
        graph=graph_name, policy=pol.describe())

    write_bench_json(entries)
    assert all(bitmatch.values()), f"device order mismatch: {bitmatch}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, few batches (CI)")
    main(**vars(ap.parse_args()))
