"""Paper Figure 5: the COMM-RAND knob sweep — val acc, per-epoch speedup,
epochs-to-converge ratio, total-training speedup vs the uniform baseline."""
from __future__ import annotations

from benchmarks.common import (POLICIES, calibrator, dataset, emit, gnn_cfg,
                               quick_tcfg)
from repro.batching import CommRandPolicy
from repro.train.gnn_loop import train_once


def main(full: bool = False):
    names = ["reddit-like", "igb-small".replace("igb-small", "igb-like")] \
        if full else ["tiny"]
    p_values = (0.5, 0.9, 1.0) if full else (0.5, 1.0)
    for ds in names:
        g = dataset(ds)
        cfg = gnn_cfg(g)
        tcfg = quick_tcfg(30 if full else 12)
        base = train_once(g, cfg, POLICIES["RAND-ROOTS/p0.5"], tcfg, seed=0,
                          calibrator=calibrator())
        emit(f"fig5/{ds}/RAND-ROOTS/p0.5", base.per_epoch_time_s * 1e6,
             f"acc={base.val_acc:.4f};epochs={base.epochs_to_converge};"
             f"total_s={base.total_time_s:.2f};speedup=1.00")
        for pol_name in ("NORAND-ROOTS", "COMM-RAND-MIX-0%",
                         "COMM-RAND-MIX-12.5%", "COMM-RAND-MIX-50%"):
            for p in p_values:
                key = f"{pol_name}/p1.0"
                pol0 = POLICIES[key]
                pol = CommRandPolicy(pol0.root_mode, pol0.mix, p)
                r = train_once(g, cfg, pol, tcfg, seed=0,
                               calibrator=calibrator())
                emit(f"fig5/{ds}/{pol_name}/p{p}",
                     r.per_epoch_time_s * 1e6,
                     f"acc={r.val_acc:.4f};epochs={r.epochs_to_converge};"
                     f"total_s={r.total_time_s:.2f};"
                     f"speedup={base.total_time_s / r.total_time_s:.2f};"
                     f"per_epoch_speedup="
                     f"{base.per_epoch_time_s / r.per_epoch_time_s:.2f}")


if __name__ == "__main__":
    main()
