"""Shared benchmark helpers. All batch construction flows through
`repro.batching`: policies come from the registry and caps from a shared
`CapsCalibrator` whose JSON cache under `artifacts/` lets repeated sweeps
skip the numpy calibration probe."""
from __future__ import annotations

import functools
import os
import time

import numpy as np

from repro.batching import CapsCalibrator, make_policy, root_batches
from repro.configs.base import GNNConfig, TrainConfig
from repro.core.reorder import prepare
from repro.graphs import synthetic

POLICIES = {
    "RAND-ROOTS/p0.5": make_policy("rand"),
    "NORAND-ROOTS/p1.0": make_policy("norand"),
    "COMM-RAND-MIX-0%/p1.0": make_policy("comm_rand", mix=0.0, p=1.0),
    "COMM-RAND-MIX-12.5%/p1.0": make_policy("comm_rand", mix=0.125, p=1.0),
    "COMM-RAND-MIX-25%/p1.0": make_policy("comm_rand", mix=0.25, p=1.0),
    "COMM-RAND-MIX-50%/p1.0": make_policy("comm_rand", mix=0.5, p=1.0),
}

CAPS_CACHE = os.path.join(os.path.dirname(__file__), "artifacts",
                          "caps_cache.json")


def calibrator(seed: int = 0) -> CapsCalibrator:
    """Disk-cached calibrator shared by every GNN benchmark driver."""
    return CapsCalibrator(cache_path=CAPS_CACHE, seed=seed)


def epoch_batches(g, policy, batch_size: int, seed: int = 0) -> np.ndarray:
    """One epoch of root-id batches through the `repro.batching` API."""
    return root_batches(g, policy, batch_size, seed=seed)


@functools.lru_cache(maxsize=None)
def dataset(name: str):
    return prepare(synthetic.load(name), oracle=True)


def gnn_cfg(g, layers=2, hidden=64, fanout=(10, 10)) -> GNNConfig:
    return GNNConfig(f"sage-{g.name}", "sage", layers, hidden, g.feat_dim,
                     g.num_classes, fanout=fanout)


def quick_tcfg(max_epochs=15, batch=512) -> TrainConfig:
    return TrainConfig(batch_size=batch, max_epochs=max_epochs,
                       early_stop_patience=5)


def timer_us(fn, *args, warmup=1, iters=3) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def measured_static_miss(plan, stream) -> dict:
    """Replay a host access stream through the DEVICE hit counters of a
    `repro.featcache.CachePlan` — the measured (not simulated) numbers
    fig9/fig10 report next to the LRU simulation.

    Returns {"miss_rate", "miss_per_batch"}. miss_per_batch (missed rows
    per batch = feature rows actually fetched from the global matrix) is
    the HBM-traffic quantity behind the paper's Fig-10 speedups and the
    one the drivers assert orderings on: the per-ACCESS rate divides by
    each policy's own footprint, normalizing away exactly the working-set
    reduction COMM-RAND exists to create."""
    import jax.numpy as jnp

    from repro import featcache
    h = m = nb = 0
    for ids in stream:
        hh, mm = featcache.cache_stats(
            plan.pos, jnp.asarray(ids, jnp.int32), plan.pos.shape[0])
        h += int(hh)
        m += int(mm)
        nb += 1
    return {"miss_rate": 1.0 - h / max(h + m, 1),
            "miss_per_batch": m / max(nb, 1)}


def measured_dynamic_miss(plan, stream, feats, epochs: int = 2) -> dict:
    """Measured numbers of the DYNAMIC CLOCK cache (`featcache.dynamic`)
    over a host access stream: seed the state from `plan`, replay the
    stream for `epochs` passes feeding the reference-bit/frequency
    accumulators exactly like the trainer's steps do, run the
    epoch-boundary refill between passes, and report the LAST pass — the
    steady-state analogue of the trainer's per-epoch measurement. Pass 1
    is bit-identical to the static plan (same residency); the refill then
    re-admits against the distribution the cache ACTUALLY served, which
    is the paper's dynamic-cache story and why the measured
    missed-rows-per-batch can only improve on the static plan when the
    stream repeats. Returns {"miss_rate", "miss_per_batch", "admitted"}."""
    import jax.numpy as jnp

    from repro import featcache
    from repro.featcache import dynamic

    state = dynamic.from_plan(plan)
    feats = jnp.asarray(feats)
    admitted = 0
    h = m = nb = 0
    for e in range(epochs):
        h = m = nb = 0
        for ids in stream:
            d = jnp.asarray(ids, jnp.int32)
            hh, mm = featcache.cache_stats(state.pos, d,
                                           state.pos.shape[0])
            state = dynamic.with_refs(state, dynamic.ref_updates(state, d))
            h += int(hh)
            m += int(mm)
            nb += 1
        if e < epochs - 1:
            state, adm = dynamic.refill(state, feats)
            admitted += int(adm)
    return {"miss_rate": 1.0 - h / max(h + m, 1),
            "miss_per_batch": m / max(nb, 1),
            "admitted": admitted}


_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_REPO_ROOT, "BENCH_kernels.json")
BENCH_CACHE_JSON = os.path.join(_REPO_ROOT, "BENCH_cache.json")


def write_bench_json(entries: dict, path: str = BENCH_JSON) -> None:
    """Merge `entries` into a machine-readable bench artifact at the repo
    root (BENCH_kernels.json by default; fig9/fig10 target
    BENCH_cache.json) — the perf trajectory future PRs diff against.
    Existing keys from other bench drivers are preserved."""
    import json

    from repro.obs.metrics import run_metadata
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data.update(entries)
    # shared run-metadata header (repro.obs): schema/backend/jax/
    # git_commit/hostname — CI asserts these keys on every artifact
    data["_meta"] = dict(
        run_metadata(),
        note="off-TPU, pallas runs in interpret mode: "
             "us timings there are shape-validation only; "
             "compare the analytic hbm_bytes")
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
