"""Shared benchmark helpers."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.configs.base import (BASELINE_POLICY, CommRandPolicy, GNNConfig,
                                TrainConfig)
from repro.core.reorder import prepare
from repro.graphs import synthetic

POLICIES = {
    "RAND-ROOTS/p0.5": BASELINE_POLICY,
    "NORAND-ROOTS/p1.0": CommRandPolicy("norand", 0.0, 1.0),
    "COMM-RAND-MIX-0%/p1.0": CommRandPolicy("comm_rand", 0.0, 1.0),
    "COMM-RAND-MIX-12.5%/p1.0": CommRandPolicy("comm_rand", 0.125, 1.0),
    "COMM-RAND-MIX-25%/p1.0": CommRandPolicy("comm_rand", 0.25, 1.0),
    "COMM-RAND-MIX-50%/p1.0": CommRandPolicy("comm_rand", 0.5, 1.0),
}


@functools.lru_cache(maxsize=None)
def dataset(name: str):
    return prepare(synthetic.load(name), oracle=True)


def gnn_cfg(g, layers=2, hidden=64, fanout=(10, 10)) -> GNNConfig:
    return GNNConfig(f"sage-{g.name}", "sage", layers, hidden, g.feat_dim,
                     g.num_classes, fanout=fanout)


def quick_tcfg(max_epochs=15, batch=512) -> TrainConfig:
    return TrainConfig(batch_size=batch, max_epochs=max_epochs,
                       early_stop_patience=5)


def timer_us(fn, *args, warmup=1, iters=3) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
