"""Paper Figure 9 (software cache): LRU miss rates per policy. Paper's
A100 numbers for reference: baseline 35.46%, COMM-RAND-MIX-{50,25,12.5,0}%
= {20.99, 11.39, 6.22, 6.21}%."""
from __future__ import annotations

from benchmarks.common import POLICIES, dataset, emit
from repro.core.cachesim import lru_miss_rate, policy_access_stream


def main(full: bool = False):
    g = dataset("reddit-like" if full else "tiny")
    capacity = int(g.num_nodes * (0.2 if full else 0.6))
    for name, pol in POLICIES.items():
        stream = policy_access_stream(g, pol, 512, (10, 10), n_batches=8)
        miss = lru_miss_rate(stream, capacity)
        emit(f"fig9/{g.name}/{name}", 0.0, f"miss_rate={miss:.4f}")


if __name__ == "__main__":
    main()
