"""Paper Figure 9 (software cache): per-policy miss rates, simulated AND
measured. Paper's A100 numbers for reference: baseline 35.46%,
COMM-RAND-MIX-{50,25,12.5,0}% = {20.99, 11.39, 6.22, 6.21}%.

Two columns per policy now that the cache exists (`repro.featcache`):

  lru/clock   simulated dynamic caches (vectorized stack-distance LRU +
              second-chance CLOCK) replaying the policy's access stream
  static/*    MEASURED numbers of real `CachePlan`s (one per admission
              policy) over the same stream, counted by the device-side
              `gather_cached` hit counters — presampled plans are built
              from a DIFFERENT seed than the measured stream, so the
              measurement is held out

Results land in BENCH_cache.json at the repo root (alongside the text
`emit` lines). `--smoke` is the CI entry point (tiny graph, short stream);
it also asserts the Fig-9 ordering: COMM-RAND-MIX-0% misses less than
RAND-ROOTS under both the LRU simulation and the static plans' per-batch
miss traffic (see `measured_static_miss` for why traffic, not per-access
rate, is the stable measured quantity).
"""
from __future__ import annotations

from benchmarks.common import (BENCH_CACHE_JSON, POLICIES, dataset, emit,
                               measured_static_miss, write_bench_json)
from repro import featcache

ADMISSIONS = ("degree_hot", "community_freq", "presampled_freq")


def main(full: bool = False, smoke: bool = False):
    g = dataset("reddit-like" if full else "tiny")
    n_batches = 6 if smoke else 8
    capacity = int(g.num_nodes * (0.2 if full else 0.6))
    entries = {}
    for name, pol in POLICIES.items():
        stream = featcache.policy_access_stream(
            g, pol, 512, (10, 10), n_batches=n_batches)
        row = {
            "capacity": capacity,
            "lru_miss": featcache.lru_miss_rate(stream, capacity),
            "clock_miss": featcache.clock_miss_rate(stream, capacity),
            "static_miss": {},
            "static_miss_per_batch": {},
        }
        for adm in ADMISSIONS:
            plan = featcache.build_plan(
                g, adm, capacity=capacity, policy=pol, batch_size=512,
                fanouts=(10, 10), seed=1)       # held out: stream seed is 0
            m = measured_static_miss(plan, stream)
            # the device counters must agree with the host replay
            host = featcache.static_miss_rate(stream, plan.cached_ids())
            assert abs(m["miss_rate"] - host) < 1e-9, (name, adm, m, host)
            row["static_miss"][adm] = m["miss_rate"]
            row["static_miss_per_batch"][adm] = m["miss_per_batch"]
        entries[f"fig9/{g.name}/{name}"] = row
        emit(f"fig9/{g.name}/{name}", 0.0,
             f"miss_rate={row['lru_miss']:.4f};"
             f"clock={row['clock_miss']:.4f};"
             f"static_presampled={row['static_miss']['presampled_freq']:.4f}")
    write_bench_json(entries, BENCH_CACHE_JSON)

    # Fig-9 ordering: structure-aware batches miss less, simulated and real
    cr = entries[f"fig9/{g.name}/COMM-RAND-MIX-0%/p1.0"]
    base = entries[f"fig9/{g.name}/RAND-ROOTS/p0.5"]
    assert cr["lru_miss"] < base["lru_miss"], (cr, base)
    assert min(cr["static_miss_per_batch"].values()) < \
        min(base["static_miss_per_batch"].values()), (cr, base)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: short stream on the tiny graph")
    a = ap.parse_args()
    main(full=a.full, smoke=a.smoke)
