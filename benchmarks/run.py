"""Benchmark harness — one module per paper table/figure (DESIGN.md §8).

Prints ``name,us_per_call,derived`` CSV. Default scale is CPU-quick (tiny
synthetic graphs, few epochs); pass --full for the EXPERIMENTS.md-scale
sweeps. The dry-run / roofline artifacts are produced separately by
``python -m repro.launch.dryrun`` (they need 512 fake devices).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="EXPERIMENTS.md-scale sweeps (slow)")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()

    from benchmarks import (bench_train_step, fig5_knob_sweep,
                            fig6_footprint, fig7_label_diversity,
                            fig8_trainset_size, fig9_cachesim,
                            fig10_cache_capacity, kernels_bench,
                            sampler_bench, table3_fixed_budget,
                            table4_prior_work, table5_models)
    mods = [
        ("fig5", fig5_knob_sweep), ("fig6", fig6_footprint),
        ("fig7", fig7_label_diversity), ("table3", table3_fixed_budget),
        ("table4", table4_prior_work), ("fig8", fig8_trainset_size),
        ("fig9", fig9_cachesim), ("fig10", fig10_cache_capacity),
        ("table5", table5_models), ("kernels", kernels_bench),
        ("train_step", bench_train_step), ("samplers", sampler_bench),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in mods:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod.main(full=args.full)
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
