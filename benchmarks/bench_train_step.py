"""Timing of the jit'd GNN train/eval steps for sage/gcn/gat on BOTH
`agg_impl` paths, plus the jaxpr-level check that the fused path removed
the up-front (cap_L, F) feature pre-gather from the compiled train step.

Off-TPU the "pallas" rows run the kernels in interpret mode — those wall
times validate shapes/plumbing, not throughput (see BENCH_kernels.json
`_meta`). Results merge into BENCH_kernels.json at the repo root.
"""
from __future__ import annotations

import jax

from benchmarks.common import (calibrator, dataset, emit, quick_tcfg,
                               timer_us, write_bench_json)
from repro.batching import make_policy
from repro.configs.base import GNNConfig
from repro.train.gnn_loop import GNNTrainer

MODELS = ("sage", "gcn", "gat")


def _pre_gather_in_jaxpr(tr: GNNTrainer, batch) -> bool:
    """True iff the compiled train step still materializes the input-level
    (cap_L, F) feature copy (an f32[cap_L, F] intermediate)."""
    cap_l = int(batch.node_ids.shape[0])
    feat = int(tr.feats.shape[1])
    jaxpr = jax.make_jaxpr(tr.train_step)(
        tr.params, tr.opt_state, batch, tr.feats, tr.degrees, 1e-3,
        jax.random.key(0), tr.cache)
    return f"f32[{cap_l},{feat}]" in str(jaxpr)


def main(full: bool = False):
    g = dataset("tiny")
    tcfg = quick_tcfg(batch=256)
    pol = make_policy("comm_rand", mix=0.125, p=1.0)
    fanout = (8, 8) if full else (5, 5)
    entries = {}
    for model in MODELS:
        for impl in ("jnp", "pallas"):
            cfg = GNNConfig(f"{model}-bench", model, 2, 64, g.feat_dim,
                            g.num_classes, fanout=fanout, agg_impl=impl)
            tr = GNNTrainer(g, cfg, tcfg, pol, seed=0,
                            calibrator=calibrator())
            batch = next(iter(tr.stream))
            us_train = timer_us(tr.train_step, tr.params, tr.opt_state,
                                batch, tr.feats, tr.degrees, 1e-3,
                                jax.random.key(0), tr.cache)
            us_eval = timer_us(tr.eval_step, tr.params, batch, tr.feats,
                               tr.degrees, tr.cache)
            pre = _pre_gather_in_jaxpr(tr, batch)
            cap_l = int(batch.node_ids.shape[0])
            emit(f"train_step/{model}/{impl}", us_train,
                 f"cap_L={cap_l};pre_gather={pre}")
            emit(f"eval_step/{model}/{impl}", us_eval, f"cap_L={cap_l}")
            entries[f"train_step/{model}/{impl}"] = {
                "us_per_call": round(us_train, 1),
                "eval_us_per_call": round(us_eval, 1),
                "cap_L": cap_l, "feat_dim": int(g.feat_dim),
                "pre_gather_in_jaxpr": pre,
            }
    write_bench_json(entries)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(full=args.full)
