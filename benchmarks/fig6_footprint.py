"""Paper Figure 6: per-epoch time correlates with the gathered input
feature bytes; community bias shrinks both."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (POLICIES, calibrator, dataset, emit, gnn_cfg,
                               quick_tcfg)
from repro.train.gnn_loop import train_once


def main(full: bool = False):
    g = dataset("reddit-like" if full else "tiny")
    cfg = gnn_cfg(g)
    tcfg = quick_tcfg(6)
    times, bytes_ = [], []
    for name, pol in POLICIES.items():
        r = train_once(g, cfg, pol, tcfg, seed=0, calibrator=calibrator())
        times.append(r.per_epoch_time_s)
        bytes_.append(r.feature_bytes_per_batch)
        emit(f"fig6/{g.name}/{name}", r.per_epoch_time_s * 1e6,
             f"feature_MB_per_batch={r.feature_bytes_per_batch / 2**20:.2f};"
             f"uniq={r.mean_unique_nodes:.0f}")
    corr = float(np.corrcoef(times, bytes_)[0, 1])
    emit(f"fig6/{g.name}/pearson", 0.0, f"corr={corr:.3f}")


if __name__ == "__main__":
    main()
