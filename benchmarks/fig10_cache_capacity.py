"""Paper Figure 10: COMM-RAND's advantage grows as cache capacity shrinks
(MIG L2-cut analogue, modeled via the LRU simulator)."""
from __future__ import annotations

from benchmarks.common import POLICIES, dataset, emit
from repro.core.cachesim import lru_miss_rate, policy_access_stream


def main(full: bool = False):
    g = dataset("reddit-like" if full else "tiny")
    base = POLICIES["RAND-ROOTS/p0.5"]
    cr = POLICIES["COMM-RAND-MIX-0%/p1.0"]
    s_base = policy_access_stream(g, base, 512, (10, 10), n_batches=8)
    s_cr = policy_access_stream(g, cr, 512, (10, 10), n_batches=8, seed=1)
    for frac in (0.8, 0.6, 0.4, 0.2):
        cap = max(int(g.num_nodes * frac), 16)
        m_b = lru_miss_rate(s_base, cap)
        m_c = lru_miss_rate(s_cr, cap)
        emit(f"fig10/{g.name}/cap{frac}", 0.0,
             f"baseline_miss={m_b:.4f};commrand_miss={m_c:.4f};"
             f"advantage={m_b / max(m_c, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
