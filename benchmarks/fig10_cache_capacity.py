"""Paper Figure 10: COMM-RAND's advantage grows as cache capacity shrinks
(MIG L2-cut analogue). Each capacity point reports THREE measured/modelled
columns per policy:

  *_lru / *_clock        simulated dynamic caches (vectorized
                         stack-distance LRU + second-chance CLOCK replay)
  *_static[_per_batch]   MEASURED misses of a real presampled `CachePlan`
                         at that capacity, counted by the device-side
                         `gather_cached` counters (plans presampled from a
                         held-out seed)
  *_dynamic[_per_batch]  MEASURED misses of the real on-device CLOCK
                         admission loop (`featcache.dynamic`): the static
                         plan promoted to a `DynamicCacheState`, one
                         adaptation epoch (reference bits + epoch refill),
                         then the measured pass — the trainer's
                         steady-state cache

The asserted measured quantity is missed rows PER BATCH — the HBM-traffic
number behind the paper's speedups (see `common.measured_static_miss`).
The dynamic column must be <= the static plan at EVERY capacity (the
refill only swaps in rows that out-accessed their victims). Results land
in BENCH_cache.json; CI re-asserts the orderings from the artifact.
`--smoke` is the CI entry point.
"""
from __future__ import annotations

from benchmarks.common import (BENCH_CACHE_JSON, POLICIES, dataset, emit,
                               measured_dynamic_miss, measured_static_miss,
                               write_bench_json)
from repro import featcache


def main(full: bool = False, smoke: bool = False):
    g = dataset("reddit-like" if full else "tiny")
    n_batches = 6 if smoke else 8
    base = POLICIES["RAND-ROOTS/p0.5"]
    cr = POLICIES["COMM-RAND-MIX-0%/p1.0"]
    s_base = featcache.policy_access_stream(
        g, base, 512, (10, 10), n_batches=n_batches)
    s_cr = featcache.policy_access_stream(
        g, cr, 512, (10, 10), n_batches=n_batches, seed=1)
    entries = {}
    for frac in (0.8, 0.6, 0.4, 0.2):
        cap = max(int(g.num_nodes * frac), 16)
        row = {"capacity": cap,
               "baseline_lru": featcache.lru_miss_rate(s_base, cap),
               "commrand_lru": featcache.lru_miss_rate(s_cr, cap),
               "baseline_clock": featcache.clock_miss_rate(s_base, cap),
               "commrand_clock": featcache.clock_miss_rate(s_cr, cap)}
        for col, pol, stream, seed in (
                ("baseline", base, s_base, 2),
                ("commrand", cr, s_cr, 3)):
            plan = featcache.build_plan(
                g, "presampled_freq", capacity=cap, policy=pol,
                batch_size=512, fanouts=(10, 10), seed=seed)
            m = measured_static_miss(plan, stream)
            row[col + "_static"] = m["miss_rate"]
            row[col + "_static_per_batch"] = m["miss_per_batch"]
            d = measured_dynamic_miss(plan, stream, g.features)
            row[col + "_dynamic"] = d["miss_rate"]
            row[col + "_dynamic_per_batch"] = d["miss_per_batch"]
            row[col + "_dynamic_admitted"] = d["admitted"]
        row["advantage"] = row["baseline_lru"] / max(row["commrand_lru"],
                                                     1e-9)
        entries[f"fig10/{g.name}/cap{frac}"] = row
        emit(f"fig10/{g.name}/cap{frac}", 0.0,
             f"baseline_miss={row['baseline_lru']:.4f};"
             f"commrand_miss={row['commrand_lru']:.4f};"
             f"baseline_static_pb={row['baseline_static_per_batch']:.1f};"
             f"commrand_static_pb={row['commrand_static_per_batch']:.1f};"
             f"baseline_dynamic_pb={row['baseline_dynamic_per_batch']:.1f};"
             f"commrand_dynamic_pb={row['commrand_dynamic_per_batch']:.1f};"
             f"advantage={row['advantage']:.2f}x")
        # the Fig-10 ordering, at every capacity: simulated LRU and
        # measured static miss traffic
        assert row["commrand_lru"] < row["baseline_lru"], row
        assert row["commrand_static_per_batch"] < \
            row["baseline_static_per_batch"], row
        # the dynamic CLOCK loop never fetches more than the static plan
        # it was seeded from (the refill only swaps in rows that
        # out-accessed their victims)
        assert row["baseline_dynamic_per_batch"] <= \
            row["baseline_static_per_batch"], row
        assert row["commrand_dynamic_per_batch"] <= \
            row["commrand_static_per_batch"], row
    write_bench_json(entries, BENCH_CACHE_JSON)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: short stream on the tiny graph")
    a = ap.parse_args()
    main(full=a.full, smoke=a.smoke)
