"""Paper Table 5: COMM-RAND generalizes to GCN and GAT."""
from __future__ import annotations

import dataclasses

from benchmarks.common import POLICIES, dataset, emit, quick_tcfg
from repro.configs.base import GNNConfig
from repro.train.gnn_loop import train_once


def main(full: bool = False):
    g = dataset("reddit-like" if full else "tiny")
    tcfg = quick_tcfg(20 if full else 10)
    for model in ("gcn", "gat"):
        cfg = GNNConfig(f"{model}-{g.name}", model, 2, 64, g.feat_dim,
                        g.num_classes, fanout=(10, 10))
        base = train_once(g, cfg, POLICIES["RAND-ROOTS/p0.5"], tcfg, seed=0)
        cr = train_once(g, cfg, POLICIES["COMM-RAND-MIX-12.5%/p1.0"], tcfg,
                        seed=0)
        emit(f"table5/{g.name}/{model}/baseline",
             base.per_epoch_time_s * 1e6,
             f"acc={base.val_acc:.4f};epochs={base.epochs_to_converge};"
             f"total_s={base.total_time_s:.2f}")
        emit(f"table5/{g.name}/{model}/commrand",
             cr.per_epoch_time_s * 1e6,
             f"acc={cr.val_acc:.4f};epochs={cr.epochs_to_converge};"
             f"total_s={cr.total_time_s:.2f};"
             f"total_speedup={base.total_time_s / cr.total_time_s:.2f}")


if __name__ == "__main__":
    main()
