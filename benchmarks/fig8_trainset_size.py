"""Paper Figure 8: ClusterGCN per-epoch time is invariant to the training-
set size; mini-batch policies scale down with it."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import POLICIES, calibrator, dataset, emit, gnn_cfg
from repro.configs.base import TrainConfig
from repro.train.baselines import train_clustergcn
from repro.train.gnn_loop import GNNTrainer


def main(full: bool = False):
    g0 = dataset("reddit-like" if full else "tiny")
    cfg = gnn_cfg(g0)
    tcfg = TrainConfig(batch_size=512, max_epochs=3)
    fractions = (1.0, 0.5, 0.25, 0.1)
    for frac in fractions:
        n = max(int(len(g0.train_ids) * frac), 64)
        g = dataclasses.replace(g0, train_ids=g0.train_ids[:n])
        tr = GNNTrainer(g, cfg, tcfg, POLICIES["COMM-RAND-MIX-12.5%/p1.0"],
                        seed=0, calibrator=calibrator()).warmup()
        times = [tr.run_epoch(tcfg.learning_rate)["time"] for _ in range(2)]
        cg = train_clustergcn(g, cfg, tcfg, parts_per_batch=2, epochs=2)
        emit(f"fig8/{g0.name}/frac{frac}", np.mean(times) * 1e6,
             f"commrand_epoch_s={np.mean(times):.3f};"
             f"clustergcn_epoch_s={cg['per_epoch_time_s']:.3f}")


if __name__ == "__main__":
    main()
